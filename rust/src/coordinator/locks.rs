//! The winner-lock table.
//!
//! Stamp-based: `stamp[unit] == current_batch` means locked. Clearing all
//! locks between batches is O(1) (bump the stamp), and the table grows with
//! the slab so freshly inserted units are lockable immediately.

use crate::som::UnitId;

/// Per-batch winner locks (paper §2.2).
#[derive(Clone, Debug, Default)]
pub struct LockTable {
    stamp: Vec<u64>,
    current: u64,
}

impl LockTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new batch: all locks released in O(1).
    pub fn next_batch(&mut self) {
        self.current += 1;
    }

    /// Make sure `capacity` units are addressable.
    pub fn ensure_capacity(&mut self, capacity: usize) {
        if self.stamp.len() < capacity {
            self.stamp.resize(capacity, 0);
        }
    }

    /// Attempt to lock `unit` for the current batch. Returns `false` when
    /// the unit is already locked (⇒ discard the signal).
    #[inline]
    pub fn try_lock(&mut self, unit: UnitId) -> bool {
        let slot = unit as usize;
        if slot >= self.stamp.len() {
            self.stamp.resize(slot + 1, 0);
        }
        if self.stamp[slot] == self.current {
            false
        } else {
            self.stamp[slot] = self.current;
            true
        }
    }

    #[inline]
    pub fn is_locked(&self, unit: UnitId) -> bool {
        self.stamp
            .get(unit as usize)
            .is_some_and(|&s| s == self.current)
    }

    /// Locked count this batch (diagnostics).
    pub fn locked_count(&self) -> usize {
        self.stamp.iter().filter(|&&s| s == self.current).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_lock_wins() {
        let mut t = LockTable::new();
        t.next_batch();
        assert!(t.try_lock(5));
        assert!(!t.try_lock(5), "second signal with the same winner discards");
        assert!(t.try_lock(6));
        assert_eq!(t.locked_count(), 2);
    }

    #[test]
    fn next_batch_releases_everything() {
        let mut t = LockTable::new();
        t.next_batch();
        assert!(t.try_lock(1));
        assert!(t.try_lock(2));
        t.next_batch();
        assert!(!t.is_locked(1));
        assert!(t.try_lock(1));
        assert!(t.try_lock(2));
    }

    #[test]
    fn grows_on_demand() {
        let mut t = LockTable::new();
        t.next_batch();
        assert!(t.try_lock(1_000));
        assert!(!t.try_lock(1_000));
        assert!(t.try_lock(3));
    }

    #[test]
    fn fresh_table_locks_nothing() {
        let t = LockTable::new();
        assert!(!t.is_locked(0));
        assert_eq!(t.locked_count(), 0);
    }
}
