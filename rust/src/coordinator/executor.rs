//! The batch-update executor — the one implementation of the paper's §2.2
//! Update-phase discipline that every convergence driver delegates to.
//!
//! Before this module the winner-lock / staleness / random-order / sync
//! loop was triplicated across `engine::run_single_signal`,
//! `engine::run_multi_signal` and `coordinator::run_pipelined`. It now
//! lives here exactly once:
//!
//! - [`BatchExecutor::run_batch`] consumes one sampled batch with its
//!   precomputed winners and applies the paper's collision semantics: a
//!   random permutation (no RNG is drawn for the degenerate `m = 1`
//!   single-signal case), the "implicit lock on the winner unit", and the
//!   staleness guard against units inserted earlier in the same batch
//!   ([`InsertedGuard`], with an AABB early exit instead of the old
//!   O(m·inserts) linear scan).
//! - Structural changes accumulate into one merged [`ChangeLog`] that is
//!   committed to the [`FindWinners`] index with a single `sync` per batch
//!   (the deferred-commit pattern of the CUDA-SSO line of work) instead of
//!   one `sync` per signal. `Indexed::sync` reconciles per unit, so the
//!   merged log is equivalent to the per-signal sequence.
//!
//! With `update_threads > 1` the executor additionally splits the Update
//! phase — the paper's own named bottleneck once Find Winners is
//! accelerated (§3.3) — into:
//!
//! 1. a **sequential admission pass** in permutation order (locks,
//!    staleness, aliveness: exactly the paper's collision semantics).
//!    Admitted signals whose updates are provably pure adaptation
//!    ([`UpdateKind::Adapt`], classified against `signals_seen +
//!    pending_commits` so even GNG's global insertion schedule is decided
//!    exactly) and whose winner neighborhoods are conflict-disjoint are
//!    deferred; everything else flushes the deferral queue and runs
//!    inline, preserving slab-id assignment order exactly;
//! 2. a **parallel plan pass**: deferred signals are planned off-thread
//!    via the read-only [`GrowingNetwork::plan_update`], in work-stealing
//!    chunks claimed from the run's persistent [`WorkerPool`];
//! 3. a **shard-local concurrent commit**: the network writes of every
//!    plan (edge aging, the competitive-Hebbian connect, position moves,
//!    firing levels) are applied *in parallel* through
//!    [`crate::som::ShardWriter`] — sound because deferred plans have
//!    pairwise-disjoint touched sets (`{w1, w2} ∪ N(w1)`), enforced by the
//!    conflict check at deferral time, and allocation determinism is the
//!    slab's own property (sharded free lists with a global LIFO pop
//!    order). Commit chunks are conflict-disjoint groups cut from the
//!    admission order (deterministically — chunk boundaries depend only on
//!    the pending count and worker count, never on scheduling);
//! 4. a **sequential scalar replay** in admission order on the driver
//!    thread: change-log entries (from the pre-move positions the writers
//!    captured), the shared undirected-edge counter, and each algorithm's
//!    per-signal scalars ([`GrowingNetwork::commit_scalars`]: the QE
//!    stream, GNG's signal counter / lazily-decayed winner error / decay
//!    epoch). Every order-sensitive f32 accumulation lives here, so the
//!    final state is bit-identical to the sequential `Multi` driver for
//!    any thread count and any work-stealing schedule.
//!
//! ## The region schedule (`regions > 1`)
//!
//! With a [`RegionMap`] attached ([`BatchExecutor::set_regions`]) the
//! admission/plan/commit schedule becomes **region-aware**:
//!
//! - conflict domains move from unit granularity to *region* granularity:
//!   a deferred plan marks the regions of its touched units
//!   (`{w1, w2} ∪ N(w1)`, mapped through their current positions — stable
//!   within a flush window), and a signal conflicts iff one of its touched
//!   regions is marked. Region overlap is implied by unit overlap, so this
//!   is a sound coarsening: it can only flush *earlier*, and flush timing
//!   is invisible in the results (what is planned and committed per signal
//!   never changes — even GNG's `pending_commits` classification is a
//!   flush-invariant of the admission order, since `signals_seen +
//!   pending` counts every admitted signal exactly once either way);
//! - signals landing in **disjoint region neighborhoods flow through plan
//!   *and* structural commit concurrently**: an [`UpdateKind::Insert`]
//!   update no longer flushes the deferral queue. Its slab allocation runs
//!   sequentially at admission ([`GrowingNetwork::begin_insert`] — the
//!   sharded free lists' global-LIFO pop keeps unit ids bit-identical to
//!   the sequential driver, which subsumes the earlier plan of
//!   per-commit-group home-shard allocation: allocation is off the commit
//!   path entirely), the staleness-guard entry is pushed at the same
//!   admission position the sequential driver would, and the edge work
//!   commits concurrently with the adapt plans through
//!   [`crate::som::ShardWriter::commit_insert`].
//!
//! Without a region map (`regions = 1`), `Insert` degenerates to
//! `Structural` and the schedule is exactly the pre-region behavior.

use std::sync::{Arc, Mutex};

use crate::findwinners::FindWinners;
use crate::geometry::{Aabb, Vec3};
use crate::rng::Rng;
use crate::runtime::{resolve_threads, steal_chunk, WorkerPool};
use crate::som::{
    ChangeLog, GrowingNetwork, Network, PlanKind, RegionMap, UpdateKind, UpdatePlan, Winners,
};

use super::locks::LockTable;

/// Deferred plan passes shorter than this are computed inline. A pooled
/// handoff is one mutex/condvar round-trip (≈ a few µs), far below the
/// tens of µs the old per-flush `thread::scope` spawn cost, so the
/// break-even sits well under the big steady-state batches of a mature
/// network (m up to 8192).
const MIN_PARALLEL_FLUSH: usize = 128;

/// Floor (in plans) of one work-stealing chunk in the plan pass and the
/// concurrent commit: below this the atomic claim + mutex take overhead
/// beats the ≈100–300 ns of work per plan.
const MIN_STEAL_CHUNK: usize = 32;

/// Staleness guard: positions of units inserted earlier in the current
/// batch. A signal whose (stale) winner distance exceeds its distance to
/// one of these has effectively been won by the new unit — the paper's
/// staleness policy discards it, otherwise several stale winners around one
/// gap each insert a unit into it and the network over-grows.
///
/// `supersedes` is the hot check: an AABB over the inserted positions gives
/// an O(1) early exit (`dist²(signal, box) ≥ d1²` ⇒ no insert can be
/// closer), falling back to the exact linear scan only when the box is
/// within range. The AABB lower-bounds every member distance in f32
/// (see [`Aabb::dist2`]), so the result is identical to the plain scan.
#[derive(Clone, Debug)]
pub struct InsertedGuard {
    points: Vec<Vec3>,
    bounds: Aabb,
}

impl Default for InsertedGuard {
    fn default() -> Self {
        Self { points: Vec::new(), bounds: Aabb::EMPTY }
    }
}

impl InsertedGuard {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn clear(&mut self) {
        self.points.clear();
        self.bounds = Aabb::EMPTY;
    }

    pub fn push(&mut self, p: Vec3) {
        self.points.push(p);
        self.bounds.expand(p);
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Was any batch insert strictly closer to `signal` than `d1_sq`?
    #[inline]
    pub fn supersedes(&self, signal: Vec3, d1_sq: f32) -> bool {
        if self.points.is_empty() || self.bounds.dist2(signal) >= d1_sq {
            return false;
        }
        self.points.iter().any(|p| signal.dist2(*p) < d1_sq)
    }
}

/// One admitted-but-deferred signal awaiting its plan/commit: an
/// adapt-class signal still to be planned, or an insert-class signal whose
/// plan was already built at admission (`Insert` plans carry the
/// sequentially pre-allocated unit and are skipped by the plan pass).
#[derive(Clone, Copy, Debug)]
struct Pending {
    signal: Vec3,
    w: Winners,
    kind: PlanKind,
}

/// One claimable work item in the pooled plan pass: a pending chunk and
/// the matching plan-output chunk. Chunks are claimed through the pool's
/// work-stealing index; the `Mutex<Option<…>>` hands the `&mut` chunk to
/// exactly one claimant.
type PlanJob<'a> = Mutex<Option<(&'a [Pending], &'a mut [UpdatePlan])>>;

/// One claimable commit group in the concurrent commit pass: a contiguous,
/// conflict-disjoint slice of plans in admission order.
type CommitJob<'a> = Mutex<Option<&'a mut [UpdatePlan]>>;

/// The unified Update-phase executor (see module docs).
pub struct BatchExecutor {
    /// Resolved worker count (≥ 1).
    threads: usize,
    /// Minimum pending-plan count before a flush is handed to the worker
    /// pool ([`MIN_PARALLEL_FLUSH`]; lowered by tests to exercise the
    /// pooled path on small batches).
    flush_threshold: usize,
    locks: LockTable,
    /// Stamp set of units whose state the deferred plans read or write
    /// (unit-granular conflict domains; unused when a region map is
    /// attached).
    touched: LockTable,
    /// Region-granular conflict domains (see the module docs): stamp set
    /// of regions touched by the deferred plans.
    region_touched: LockTable,
    /// Region geometry for the region-aware schedule (None = unit-granular
    /// conflicts, inserts flush inline — the pre-region behavior).
    region_map: Option<RegionMap>,
    /// Insert-class signals deferred through the region schedule (stat for
    /// benches and the engagement assertions in tests).
    inserts_deferred: u64,
    /// Region ids of the current signal's touched set `{w1, w2} ∪ N(w1)`
    /// — computed once per admission ([`Self::fill_region_scratch`]) and
    /// shared by the conflict check and the deferral marks (refreshed
    /// after a flush, whose commits may move the touched units across
    /// region boundaries).
    region_scratch: Vec<u32>,
    order: Vec<u32>,
    log: ChangeLog,
    guard: InsertedGuard,
    pending: Vec<Pending>,
    plans: Vec<UpdatePlan>,
    /// Persistent workers for the plan pass — created once per engine run
    /// (never per flush), possibly shared with Find-Winners sharding.
    pool: Option<Arc<WorkerPool>>,
}

impl BatchExecutor {
    /// `update_threads`: 0 = auto-detect, 1 = sequential (the exact `Multi`
    /// loop), n > 1 = parallel plan pass with n persistent workers. The
    /// final network is identical for every value.
    pub fn new(update_threads: usize) -> Self {
        Self::with_pool(update_threads, None)
    }

    /// Like [`Self::new`], but reusing a caller-provided worker pool (the
    /// engine shares one pool between the plan pass and `find_threads`
    /// sharding). When `pool` is `None` and the resolved thread count
    /// exceeds 1, a dedicated pool is created here — once per executor,
    /// which the drivers construct once per run.
    pub fn with_pool(update_threads: usize, pool: Option<Arc<WorkerPool>>) -> Self {
        let mut threads = resolve_threads(update_threads);
        let pool = match pool {
            Some(p) => {
                // Sizing only (not a correctness guard): `run_indexed`
                // claims every job index no matter the worker count, but
                // activating more workers than the pool has would just be
                // clamped inside `WorkerPool::run` anyway — keep the two
                // counts honest here so chunk sizing sees the real width.
                threads = threads.min(p.size());
                Some(p)
            }
            None if threads > 1 => Some(Arc::new(WorkerPool::new(threads))),
            None => None,
        };
        Self {
            threads,
            flush_threshold: MIN_PARALLEL_FLUSH,
            locks: LockTable::new(),
            touched: LockTable::new(),
            region_touched: LockTable::new(),
            region_map: None,
            inserts_deferred: 0,
            region_scratch: Vec::new(),
            order: Vec::new(),
            log: ChangeLog::default(),
            guard: InsertedGuard::new(),
            pending: Vec::new(),
            plans: Vec::new(),
            pool,
        }
    }

    /// Resolved worker count (≥ 1).
    pub fn update_threads(&self) -> usize {
        self.threads
    }

    /// Attach the region geometry: conflict domains become region-granular
    /// and `Insert`-class updates join the deferred plan/commit flow (see
    /// the module docs). Results are bit-identical with or without a map —
    /// only flush timing and where work runs change.
    pub fn set_regions(&mut self, map: RegionMap) {
        self.region_map = Some(map);
    }

    /// Insert-class signals that flowed through the deferred commit (0
    /// without a region map).
    pub fn inserts_deferred(&self) -> u64 {
        self.inserts_deferred
    }

    /// Lower the thread-spawn break-even for tests (results are identical
    /// either way; only where plans are computed changes).
    #[cfg(test)]
    fn set_flush_threshold(&mut self, n: usize) {
        self.flush_threshold = n;
    }

    /// Run the Update phase for one batch: apply every admissible signal in
    /// a random order under the winner-lock discipline, then commit the
    /// merged change log to `fw` with a single `sync`. Returns the number
    /// of discarded signals (collisions + stale winners + absent winners).
    ///
    /// The degenerate `m = 1` case is the single-signal basic iteration:
    /// the permutation of one element draws no RNG, the lock always
    /// succeeds and the guard is empty, so the behavior (and the RNG
    /// stream) is exactly the classic loop's.
    pub fn run_batch(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        fw: &mut dyn FindWinners,
        signals: &[Vec3],
        winners: &[Option<Winners>],
        rng: &mut Rng,
    ) -> u64 {
        debug_assert_eq!(signals.len(), winners.len());
        let m = signals.len();
        // "in a random order" (paper §2.2); a 1-permutation is draw-free.
        rng.permutation(m, &mut self.order);
        self.locks.next_batch();
        self.locks.ensure_capacity(algo.net().capacity());
        self.guard.clear();
        self.log.clear();

        let mut discarded = 0u64;
        if self.threads > 1 && m > 1 {
            self.parallel_batch(algo, signals, winners, &mut discarded);
        } else {
            self.sequential_batch(algo, signals, winners, &mut discarded);
        }

        if !self.log.is_empty() {
            fw.sync(algo.net(), &self.log);
        }
        discarded
    }

    /// The paper's admission rule, short-circuit order preserved: stale
    /// winners (dead, or superseded by a same-batch insert) and locked
    /// winners all discard the signal; the lock is only taken when every
    /// earlier check passed.
    #[inline]
    fn admit(
        net: &Network,
        locks: &mut LockTable,
        guard: &InsertedGuard,
        signal: Vec3,
        w: &Winners,
    ) -> bool {
        net.is_alive(w.w1)
            && net.is_alive(w.w2)
            && !guard.supersedes(signal, w.d1_sq)
            && locks.try_lock(w.w1)
    }

    /// Apply one admitted signal inline and track its insertions for the
    /// staleness guard.
    fn apply_inline(&mut self, algo: &mut dyn GrowingNetwork, signal: Vec3, w: &Winners) {
        let inserted_before = self.log.inserted.len();
        algo.update(signal, w, &mut self.log);
        for k in inserted_before..self.log.inserted.len() {
            let id = self.log.inserted[k];
            self.guard.push(algo.net().pos(id));
        }
    }

    /// The sequential Update loop — the reference semantics (and the exact
    /// pre-refactor `Multi` behavior).
    fn sequential_batch(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        signals: &[Vec3],
        winners: &[Option<Winners>],
        discarded: &mut u64,
    ) {
        let m = self.order.len();
        for idx in 0..m {
            let j = self.order[idx] as usize;
            let w = match winners[j] {
                Some(w) => w,
                None => {
                    *discarded += 1;
                    continue;
                }
            };
            let signal = signals[j];
            if !Self::admit(algo.net(), &mut self.locks, &self.guard, signal, &w) {
                *discarded += 1;
                continue;
            }
            self.apply_inline(algo, signal, &w);
        }
    }

    /// Admission + deferred plan/commit protocol (see module docs). The
    /// admission decisions, the commit order and every floating-point
    /// result are identical to [`Self::sequential_batch`]; only *where*
    /// adapt plans are computed differs.
    fn parallel_batch(
        &mut self,
        algo: &mut dyn GrowingNetwork,
        signals: &[Vec3],
        winners: &[Option<Winners>],
        discarded: &mut u64,
    ) {
        self.pending.clear();
        self.touched.next_batch();
        self.touched.ensure_capacity(algo.net().capacity());
        if let Some(map) = &self.region_map {
            self.region_touched.next_batch();
            self.region_touched.ensure_capacity(map.region_count());
        }

        let m = self.order.len();
        for idx in 0..m {
            let j = self.order[idx] as usize;
            let w = match winners[j] {
                Some(w) => w,
                None => {
                    *discarded += 1;
                    continue;
                }
            };
            let signal = signals[j];
            // Admission reads only structural state (aliveness, batch
            // inserts, locks), none of which deferred adapts can change —
            // so deciding it before the flush matches the sequential order.
            if !Self::admit(algo.net(), &mut self.locks, &self.guard, signal, &w) {
                *discarded += 1;
                continue;
            }
            // Classification and planning read the winner's neighborhood;
            // flush first if any deferred plan touches it, so both see
            // exactly the state the sequential loop would.
            let region_mode = self.region_map.is_some();
            if region_mode {
                self.fill_region_scratch(algo.net(), &w);
            }
            if self.conflicts(algo.net(), &w) {
                self.flush(algo);
                if region_mode {
                    // The flushed commits may have moved touched units
                    // across region boundaries: recompute before marking.
                    self.fill_region_scratch(algo.net(), &w);
                }
            }
            match algo.classify_update(signal, &w, self.pending.len()) {
                UpdateKind::Structural => {
                    // Inserts/removals must happen at this exact point in
                    // the permutation order (slab-id assignment, staleness
                    // guard), after every earlier deferred adapt.
                    self.flush(algo);
                    self.apply_inline(algo, signal, &w);
                }
                UpdateKind::Insert if self.region_map.is_some() => {
                    // Region schedule: allocate the unit sequentially NOW
                    // (identical slab ids — global-LIFO free lists), push
                    // the staleness-guard entry at this exact admission
                    // position, and defer the edge work to the concurrent
                    // commit. No flush: disjoint region neighborhoods keep
                    // flowing.
                    let idx = self.pending.len();
                    if self.plans.len() <= idx {
                        self.plans.resize_with(idx + 1, UpdatePlan::default);
                    }
                    algo.begin_insert(signal, &w, &mut self.plans[idx]);
                    debug_assert_eq!(self.plans[idx].kind, PlanKind::Insert);
                    let new_unit = self.plans[idx].new_unit;
                    self.guard.push(algo.net().pos(new_unit));
                    // Mark the new unit's own region too: its slot can be a
                    // *reused* one (freed by an inline removal earlier in
                    // this batch), so a later same-window signal whose
                    // precomputed winners still name this slot would pass
                    // the aliveness check and read the half-committed unit
                    // — the mark forces that signal to flush first, exactly
                    // like the sequential order requires.
                    let map = self.region_map.as_ref().expect("region mode");
                    self.region_scratch.push(map.region_of(algo.net().pos(new_unit)));
                    self.inserts_deferred += 1;
                    self.defer(algo.net(), signal, w, PlanKind::Insert);
                }
                UpdateKind::Insert => {
                    // No region map: the pre-region behavior, inline.
                    self.flush(algo);
                    self.apply_inline(algo, signal, &w);
                }
                UpdateKind::Adapt => self.defer(algo.net(), signal, w, PlanKind::Adapt),
            }
        }
        self.flush(algo);
    }

    /// Compute the region ids of `{w1, w2} ∪ N(w1)` into the scratch
    /// buffer — once per admission; the conflict check and the deferral
    /// marks both read it (region → unit lookups through current
    /// positions, stable within a flush window because nothing commits
    /// until the flush).
    fn fill_region_scratch(&mut self, net: &Network, w: &Winners) {
        let map = self.region_map.as_ref().expect("region mode");
        self.region_scratch.clear();
        self.region_scratch.push(map.region_of(net.pos(w.w1)));
        self.region_scratch.push(map.region_of(net.pos(w.w2)));
        for e in net.edges_of(w.w1) {
            self.region_scratch.push(map.region_of(net.pos(e.to)));
        }
    }

    /// Does this signal's winner neighborhood overlap any deferred plan's?
    /// Unit-granular by default; region-granular with a map attached (a
    /// sound coarsening — unit overlap implies region overlap). In region
    /// mode the caller has just filled [`Self::fill_region_scratch`] for
    /// this signal.
    fn conflicts(&self, net: &Network, w: &Winners) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        // A deferred adapt can only change N(w1) by touching w1 itself, and
        // a deferred insert's new edges appear only at commit, so the
        // current adjacency is valid for this check.
        if self.region_map.is_some() {
            self.region_scratch.iter().any(|&r| self.region_touched.is_locked(r))
        } else {
            self.touched.is_locked(w.w1)
                || self.touched.is_locked(w.w2)
                || net.edges_of(w.w1).iter().any(|e| self.touched.is_locked(e.to))
        }
    }

    /// Queue a deferred signal and mark its touched set — `{w1, w2} ∪
    /// N(w1)` as units, or as their regions (from the scratch the caller
    /// just filled, post any flush, plus the new unit's region for insert
    /// plans — a reused slot can be named by a later signal's precomputed
    /// winners) under the region schedule.
    fn defer(&mut self, net: &Network, signal: Vec3, w: Winners, kind: PlanKind) {
        if self.region_map.is_some() {
            for &r in &self.region_scratch {
                self.region_touched.try_lock(r);
            }
        } else {
            self.touched.try_lock(w.w1);
            self.touched.try_lock(w.w2);
            for e in net.edges_of(w.w1) {
                self.touched.try_lock(e.to);
            }
        }
        self.pending.push(Pending { signal, w, kind });
    }

    /// Plan every deferred signal, apply the network writes (both in
    /// parallel when the flush is worth it), then replay the shared
    /// scalars in admission order — see the module docs for why each pass
    /// lands where it does.
    fn flush(&mut self, algo: &mut dyn GrowingNetwork) {
        let n = self.pending.len();
        if n == 0 {
            return;
        }
        if self.plans.len() < n {
            self.plans.resize_with(n, UpdatePlan::default);
        }
        let workers = self.threads.min(n);
        let pooled = workers > 1 && n >= self.flush_threshold && self.pool.is_some();

        // 1. Plan pass (read-only). `&dyn GrowingNetwork` is `Sync`, the
        // pending neighborhoods are mutually disjoint, and nothing mutates
        // until the commit below. Chunks are claimed work-stealing-style;
        // `run_indexed` returns only after every active worker acked, so
        // the borrows stay scoped. Insert plans were already built (and
        // their units allocated) at admission — the pass skips them.
        if pooled {
            let pool = self.pool.as_ref().unwrap();
            let algo_ro: &dyn GrowingNetwork = &*algo;
            let chunk = steal_chunk(n, workers, MIN_STEAL_CHUNK);
            let pairs: Vec<PlanJob<'_>> = self.pending[..n]
                .chunks(chunk)
                .zip(self.plans[..n].chunks_mut(chunk))
                .map(|pair| Mutex::new(Some(pair)))
                .collect();
            pool.run_indexed(workers, pairs.len(), &|j| {
                if let Some((pend, plan)) = pairs[j].lock().unwrap().take() {
                    for (p, out) in pend.iter().zip(plan.iter_mut()) {
                        if p.kind == PlanKind::Adapt {
                            algo_ro.plan_update(p.signal, &p.w, out);
                        }
                    }
                }
            });
        } else {
            for i in 0..n {
                let p = self.pending[i];
                if p.kind == PlanKind::Adapt {
                    algo.plan_update(p.signal, &p.w, &mut self.plans[i]);
                }
            }
        }

        // 2. Concurrent commit of the network writes: the deferred plans'
        // touched sets are pairwise disjoint (that is what `conflicts`
        // guards at deferral time — insert plans' fresh units are disjoint
        // by construction), so conflict-disjoint groups — cut
        // deterministically from the admission order — commit in parallel
        // through the raw `ShardWriter` view. Which worker commits which
        // group is racy; the written bits are not a function of it.
        let writer = algo.net_mut().shard_writer();
        if pooled {
            let pool = self.pool.as_ref().unwrap();
            let chunk = steal_chunk(n, workers, MIN_STEAL_CHUNK);
            let groups: Vec<CommitJob<'_>> = self.plans[..n]
                .chunks_mut(chunk)
                .map(|group| Mutex::new(Some(group)))
                .collect();
            pool.run_indexed(workers, groups.len(), &|j| {
                if let Some(group) = groups[j].lock().unwrap().take() {
                    for plan in group.iter_mut() {
                        match plan.kind {
                            PlanKind::Adapt => writer.commit_adapt(plan),
                            PlanKind::Insert => writer.commit_insert(plan),
                        }
                    }
                }
            });
        } else {
            for plan in &mut self.plans[..n] {
                match plan.kind {
                    PlanKind::Adapt => writer.commit_adapt(plan),
                    PlanKind::Insert => writer.commit_insert(plan),
                }
            }
        }

        // 3. Sequential scalar replay in admission (= permutation) order:
        // the merged log, the edge counter and each algorithm's per-signal
        // scalars (QE, GNG's counter/error/epoch) come out exactly as in
        // the sequential loop.
        for plan in &self.plans[..n] {
            debug_assert_eq!(plan.old_pos.len(), plan.moves.len());
            for (k, &(id, _)) in plan.moves.iter().enumerate() {
                self.log.moved.push((id, plan.old_pos[k]));
            }
            if plan.kind == PlanKind::Insert {
                self.log.inserted.push(plan.new_unit);
            }
            algo.net_mut().note_edges_created(plan.new_edges as usize);
            algo.net_mut().note_edges_removed(plan.removed_edges as usize);
            algo.commit_scalars(plan, &mut self.log);
        }
        self.pending.clear();
        self.touched.next_batch();
        if self.region_map.is_some() {
            self.region_touched.next_batch();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::{BatchRust, FindWinners};
    use crate::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
    use crate::som::{Gwr, GwrParams, Network, Soam, SoamParams};

    #[test]
    fn inserted_guard_matches_linear_scan() {
        let mut rng = Rng::seed_from(7);
        let mut guard = InsertedGuard::new();
        let mut points = Vec::new();
        for _ in 0..64 {
            let p = Vec3::new(rng.f32(), rng.f32(), rng.f32());
            guard.push(p);
            points.push(p);
            for _ in 0..8 {
                let s = Vec3::new(
                    rng.f32() * 2.0 - 0.5,
                    rng.f32() * 2.0 - 0.5,
                    rng.f32() * 2.0 - 0.5,
                );
                let d1_sq = rng.f32() * 0.5;
                let want = points.iter().any(|p| s.dist2(*p) < d1_sq);
                assert_eq!(guard.supersedes(s, d1_sq), want);
            }
        }
        guard.clear();
        assert!(!guard.supersedes(Vec3::ZERO, f32::INFINITY));
    }

    /// Drive one algorithm to a mature network, then run identical batches
    /// through a sequential and a parallel executor and compare the full
    /// network state bit-for-bit.
    fn batches_match(threads: usize) {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let sampler = SurfaceSampler::new(&mesh);

        let run = |update_threads: usize| -> (Network, u64, u64) {
            let mut rng = Rng::seed_from(11);
            let mut soam = Soam::new(SoamParams {
                insertion_threshold: 0.15,
                ..SoamParams::default()
            });
            soam.init(&sampler, &mut rng);
            let mut fw = BatchRust::default();
            fw.rebuild(soam.net());
            let mut exec = BatchExecutor::new(update_threads);
            // Force the scoped-thread plan pass even on these small
            // batches — the point is to cover the threaded path.
            exec.set_flush_threshold(4);
            let mut signals = Vec::new();
            let mut winners = Vec::new();
            let mut discarded = 0u64;
            let mut applied_signals = 0u64;
            for _ in 0..400 {
                let m = crate::coordinator::MSchedule::default().m(soam.net().len());
                sampler.sample_batch(&mut rng, m, &mut signals);
                fw.find2_batch(soam.net(), &signals, &mut winners);
                discarded += exec.run_batch(&mut soam, &mut fw, &signals, &winners, &mut rng);
                applied_signals += m as u64;
            }
            (soam.net().clone(), discarded, applied_signals)
        };

        let (net_a, disc_a, sig_a) = run(1);
        let (net_b, disc_b, sig_b) = run(threads);
        assert_eq!(disc_a, disc_b, "discard decisions diverge");
        assert_eq!(sig_a, sig_b);
        assert_eq!(net_a.capacity(), net_b.capacity(), "slab id assignment diverges");
        assert_eq!(net_a.len(), net_b.len());
        assert_eq!(net_a.edge_count(), net_b.edge_count());
        for id in 0..net_a.capacity() as u32 {
            assert_eq!(net_a.is_alive(id), net_b.is_alive(id), "unit {id}");
            if !net_a.is_alive(id) {
                continue;
            }
            let (ua, ub) = (net_a.unit(id), net_b.unit(id));
            assert_eq!(ua.pos.x.to_bits(), ub.pos.x.to_bits(), "unit {id} pos.x");
            assert_eq!(ua.pos.y.to_bits(), ub.pos.y.to_bits(), "unit {id} pos.y");
            assert_eq!(ua.pos.z.to_bits(), ub.pos.z.to_bits(), "unit {id} pos.z");
            assert_eq!(ua.firing.to_bits(), ub.firing.to_bits(), "unit {id} firing");
            assert_eq!(ua.error.to_bits(), ub.error.to_bits(), "unit {id} error");
            let mut ea: Vec<(u32, u32)> =
                net_a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            let mut eb: Vec<(u32, u32)> =
                net_b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "unit {id} edges");
        }
    }

    #[test]
    fn parallel_two_threads_bit_identical_to_sequential() {
        batches_match(2);
    }

    #[test]
    fn parallel_many_threads_bit_identical_to_sequential() {
        batches_match(5);
    }

    /// Region schedule: for any (threads, regions) the final network must
    /// be bit-identical to the sequential no-region executor, and — the PR
    /// 4 acceptance point — insert-class updates must actually flow
    /// through the deferred concurrent commit instead of flushing it.
    fn region_batches_match(threads: usize, regions: usize) {
        use crate::som::RegionMap;
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let bounds = mesh.bounds();
        let sampler = SurfaceSampler::new(&mesh);

        let run = |update_threads: usize, regions: usize| -> (Network, u64, u64) {
            let mut rng = Rng::seed_from(11);
            let mut soam = Soam::new(SoamParams {
                insertion_threshold: 0.15,
                ..SoamParams::default()
            });
            soam.init(&sampler, &mut rng);
            let mut fw = BatchRust::default();
            fw.rebuild(soam.net());
            let mut exec = BatchExecutor::new(update_threads);
            if regions > 1 {
                exec.set_regions(RegionMap::new(bounds, regions));
            }
            exec.set_flush_threshold(4);
            let mut signals = Vec::new();
            let mut winners = Vec::new();
            let mut discarded = 0u64;
            for _ in 0..400 {
                let m = crate::coordinator::MSchedule::default().m(soam.net().len());
                sampler.sample_batch(&mut rng, m, &mut signals);
                fw.find2_batch(soam.net(), &signals, &mut winners);
                discarded += exec.run_batch(&mut soam, &mut fw, &signals, &winners, &mut rng);
            }
            (soam.net().clone(), discarded, exec.inserts_deferred())
        };

        let (net_a, disc_a, deferred_a) = run(1, 1);
        assert_eq!(deferred_a, 0, "no region map, nothing defers");
        let (net_b, disc_b, deferred_b) = run(threads, regions);
        assert_eq!(disc_a, disc_b, "discard decisions diverge");
        if threads > 1 && regions > 1 {
            assert!(
                deferred_b > 0,
                "region schedule never deferred an insert (threads={threads}, regions={regions})"
            );
        }
        assert_eq!(net_a.capacity(), net_b.capacity(), "slab id assignment diverges");
        assert_eq!(net_a.len(), net_b.len());
        assert_eq!(net_a.edge_count(), net_b.edge_count());
        for id in 0..net_a.capacity() as u32 {
            assert_eq!(net_a.is_alive(id), net_b.is_alive(id), "unit {id}");
            if !net_a.is_alive(id) {
                continue;
            }
            let (ua, ub) = (net_a.unit(id), net_b.unit(id));
            assert_eq!(ua.pos.x.to_bits(), ub.pos.x.to_bits(), "unit {id} pos.x");
            assert_eq!(ua.pos.y.to_bits(), ub.pos.y.to_bits(), "unit {id} pos.y");
            assert_eq!(ua.pos.z.to_bits(), ub.pos.z.to_bits(), "unit {id} pos.z");
            assert_eq!(ua.firing.to_bits(), ub.firing.to_bits(), "unit {id} firing");
            assert_eq!(ua.threshold.to_bits(), ub.threshold.to_bits(), "unit {id} threshold");
            let mut ea: Vec<(u32, u32)> =
                net_a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            let mut eb: Vec<(u32, u32)> =
                net_b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "unit {id} edges");
        }
        net_b.check_invariants().unwrap();
    }

    #[test]
    fn region_schedule_bit_identical_coarse_grid() {
        region_batches_match(3, 8);
    }

    #[test]
    fn region_schedule_bit_identical_fine_grid() {
        region_batches_match(4, 64);
    }

    #[test]
    fn region_schedule_single_region_degenerates() {
        // regions = 1 (no map attached): exactly the pre-region behavior.
        region_batches_match(3, 1);
    }

    /// Same bit-parity harness for GNG — possible at all only because the
    /// lazy error decay removed the per-signal O(N) sweep that used to
    /// classify every GNG update as Structural. Exercises the pending-aware
    /// insertion-schedule classification and the error/epoch scalar replay.
    fn gng_batches_match(threads: usize) {
        use crate::som::{Gng, GngParams};
        let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
        let sampler = SurfaceSampler::new(&mesh);

        let run = |update_threads: usize| -> (Network, u64) {
            let mut rng = Rng::seed_from(23);
            let mut gng = Gng::new(GngParams { lambda: 60, ..GngParams::default() });
            gng.init(&sampler, &mut rng);
            let mut fw = BatchRust::default();
            fw.rebuild(gng.net());
            let mut exec = BatchExecutor::new(update_threads);
            exec.set_flush_threshold(4);
            let mut signals = Vec::new();
            let mut winners = Vec::new();
            let mut discarded = 0u64;
            for _ in 0..400 {
                let m = crate::coordinator::MSchedule::default().m(gng.net().len());
                sampler.sample_batch(&mut rng, m, &mut signals);
                fw.find2_batch(gng.net(), &signals, &mut winners);
                discarded += exec.run_batch(&mut gng, &mut fw, &signals, &winners, &mut rng);
            }
            // No materialization needed before comparing: when a unit's
            // error materializes is itself part of the deterministic
            // operation sequence (winner reads, insertion scans), so the
            // stored error/epoch state is bit-identical across runs.
            (gng.net().clone(), discarded)
        };

        let (net_a, disc_a) = run(1);
        let (net_b, disc_b) = run(threads);
        assert_eq!(disc_a, disc_b, "discard decisions diverge");
        assert_eq!(net_a.capacity(), net_b.capacity(), "slab id assignment diverges");
        assert_eq!(net_a.len(), net_b.len());
        assert_eq!(net_a.edge_count(), net_b.edge_count());
        for id in 0..net_a.capacity() as u32 {
            assert_eq!(net_a.is_alive(id), net_b.is_alive(id), "unit {id}");
            if !net_a.is_alive(id) {
                continue;
            }
            let (ua, ub) = (net_a.unit(id), net_b.unit(id));
            assert_eq!(ua.pos.x.to_bits(), ub.pos.x.to_bits(), "unit {id} pos.x");
            assert_eq!(ua.pos.y.to_bits(), ub.pos.y.to_bits(), "unit {id} pos.y");
            assert_eq!(ua.pos.z.to_bits(), ub.pos.z.to_bits(), "unit {id} pos.z");
            assert_eq!(ua.error.to_bits(), ub.error.to_bits(), "unit {id} error");
            let mut ea: Vec<(u32, u32)> =
                net_a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            let mut eb: Vec<(u32, u32)> =
                net_b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb, "unit {id} edges");
        }
    }

    #[test]
    fn gng_parallel_bit_identical_to_sequential() {
        gng_batches_match(3);
    }

    #[test]
    fn gwr_classify_agrees_with_update() {
        // For random mature-network batches: Adapt-classified signals must
        // produce structure-free updates; Insert-classified signals must
        // produce exactly one insertion and nothing else.
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(3);
        let mut gwr = Gwr::new(GwrParams {
            insertion_threshold: 0.12,
            ..GwrParams::default()
        });
        gwr.init(&sampler, &mut rng);
        let mut fw = BatchRust::default();
        fw.rebuild(gwr.net());
        let mut log = ChangeLog::default();
        let mut adapt_seen = 0;
        let mut insert_seen = 0;
        let mut structural_seen = 0;
        for _ in 0..20_000 {
            let s = sampler.sample(&mut rng);
            let Some(w) = fw.find2(gwr.net(), s) else { continue };
            let kind = gwr.classify_update(s, &w, 0);
            log.clear();
            gwr.update(s, &w, &mut log);
            match kind {
                UpdateKind::Adapt => {
                    adapt_seen += 1;
                    assert!(
                        log.inserted.is_empty() && log.removed.is_empty(),
                        "Adapt-classified update changed structure"
                    );
                }
                UpdateKind::Insert => {
                    insert_seen += 1;
                    assert_eq!(log.inserted.len(), 1, "Insert must insert exactly once");
                    assert!(
                        log.removed.is_empty(),
                        "Insert-classified update removed a unit"
                    );
                }
                UpdateKind::Structural => structural_seen += 1,
            }
        }
        assert!(adapt_seen > 0, "classification never predicted Adapt");
        assert!(insert_seen > 0, "classification never predicted Insert");
        assert!(structural_seen > 0, "classification never predicted Structural");
    }

    #[test]
    fn single_element_batch_draws_no_rng() {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(5);
        let mut soam = Soam::new(SoamParams::default());
        soam.init(&sampler, &mut rng);
        let mut fw = BatchRust::default();
        fw.rebuild(soam.net());
        let mut exec = BatchExecutor::new(1);
        let s = sampler.sample(&mut rng);
        let w = fw.find2(soam.net(), s);
        let mut probe = rng.clone();
        let expected_next = probe.next_u64();
        exec.run_batch(&mut soam, &mut fw, &[s], &[w], &mut rng);
        assert_eq!(
            rng.next_u64(),
            expected_next,
            "m=1 batches must not consume RNG (single-signal parity)"
        );
    }
}
