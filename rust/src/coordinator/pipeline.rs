//! Pipelined multi-signal driver: Sample(k+1) overlaps Update(k).
//!
//! The paper leaves the Update phase sequential and notes that once Find
//! Winners is accelerated "the Update phase becomes the most time-consuming"
//! (§3.3). This driver recovers part of that cost without touching the
//! collision semantics: a dedicated sampler thread produces the *next*
//! batch while the main thread updates the current one, connected by
//! bounded channels (depth = `queue_depth`, which is the backpressure
//! knob — the sampler can never run more than `queue_depth` batches ahead).
//!
//! Semantics note: the signals of batch k+1 are sampled with the batch size
//! computed from the unit count *before* batch k's update (the request is
//! issued before the update starts). The m-schedule therefore lags one
//! batch relative to `run_multi_signal`; everything else — winner locks,
//! random update order, update rule — is identical. Batches are recycled
//! through a return channel, so the steady state allocates nothing.
//!
//! The Update phase itself is whatever [`BatchExecutor`] the caller hands
//! in: `BatchExecutor::new(1)` reproduces the historical sequential-update
//! pipelining, while an executor with `update_threads > 1` (built by
//! `engine::run_convergence` on the run's shared worker pool) composes the
//! Sample prefetch with the pooled plan pass and the concurrent commit —
//! and, with a region map attached, with the region-aware schedule
//! (deferred insert commits). Results are bit-identical for any executor
//! thread or region count, so the knobs move wall time only.

use std::sync::mpsc;
use std::time::Instant;

use crate::config::Limits;
use crate::engine::RunReport;
use crate::findwinners::FindWinners;
use crate::geometry::Vec3;
use crate::mesh::SurfaceSampler;
use crate::metrics::{Phase, PhaseClock, PhaseTimes};
use crate::rng::Rng;
use crate::som::{ChangeLog, GrowingNetwork, Winners};

use super::executor::BatchExecutor;
use super::schedule::MSchedule;

/// Run the multi-signal iteration with a pipelined Sample phase, updating
/// through the caller-built `executor` (see the module docs for how the
/// executor's `update_threads` composes with the prefetch).
pub fn run_pipelined(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
    queue_depth: usize,
    mut executor: BatchExecutor,
) -> RunReport {
    assert!(queue_depth >= 1);
    let start = Instant::now();
    let mut phase = PhaseTimes::default();
    let mut report = RunReport::new(algo.name(), "pipelined");
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    let schedule = MSchedule::new(limits.max_parallelism);
    let mut winners: Vec<Option<Winners>> = Vec::new();

    // The sampler thread owns a forked RNG stream; the main thread keeps
    // drawing permutations from `rng`. (This is why the pipelined driver is
    // an optimization variant, not a bit-replica of `run_multi_signal`.)
    let mut sampler_rng = rng.fork();

    std::thread::scope(|scope| {
        let (req_tx, req_rx) = mpsc::sync_channel::<usize>(queue_depth);
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Vec<Vec3>>(queue_depth);
        let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<Vec3>>();

        scope.spawn(move || {
            while let Ok(m) = req_rx.recv() {
                let mut buf = recycle_rx.try_recv().unwrap_or_default();
                sampler.sample_batch(&mut sampler_rng, m, &mut buf);
                if batch_tx.send(buf).is_err() {
                    break;
                }
            }
        });

        // Prime the pipeline.
        let mut requested = schedule.m(algo.net().len());
        req_tx.send(requested).expect("sampler alive");

        loop {
            report.iterations += 1;

            // 1. Sample = wait for the prefetched batch (the visible stall).
            let clock = PhaseClock::start();
            let signals = batch_rx.recv().expect("sampler alive");
            clock.stop(&mut phase, Phase::Sample);
            let m = requested;
            debug_assert_eq!(signals.len(), m);

            // Request the NEXT batch before updating this one — this is the
            // overlap. Batch size from the pre-update unit count.
            requested = schedule.m(algo.net().len());
            req_tx.send(requested).expect("sampler alive");

            // 2. Batched Find Winners.
            let clock = PhaseClock::start();
            fw.find2_batch(algo.net(), &signals, &mut winners);
            clock.stop(&mut phase, Phase::FindWinners);

            // 3. Update under winner locks, random order (shared executor).
            let clock = PhaseClock::start();
            report.discarded += executor.run_batch(algo, fw, &signals, &winners, rng);
            clock.stop(&mut phase, Phase::Update);

            report.signals += m as u64;
            let _ = recycle_tx.send(signals);

            log.clear();
            let converged = algo.housekeeping(&mut log);
            if !log.is_empty() {
                fw.sync(algo.net(), &log);
            }
            if limits.trace {
                report.push_trace(algo, &phase);
            }
            if converged {
                report.converged = true;
                break;
            }
            if report.signals >= limits.max_signals {
                break;
            }
        }
        drop(req_tx); // sampler thread exits
    });

    report.finish(algo, phase, start.elapsed());
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findwinners::BatchRust;
    use crate::mesh::{benchmark_mesh, BenchmarkShape};
    use crate::som::{Soam, SoamParams};

    fn quick_run_threads(queue_depth: usize, seed: u64, update_threads: usize) -> RunReport {
        let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
        let sampler = SurfaceSampler::new(&mesh);
        let mut rng = Rng::seed_from(seed);
        let mut soam = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw = BatchRust::default();
        let limits = Limits { max_signals: 30_000, ..Limits::default() };
        run_pipelined(
            &mut soam,
            &sampler,
            &mut fw,
            &limits,
            &mut rng,
            queue_depth,
            BatchExecutor::new(update_threads),
        )
    }

    fn quick_run(queue_depth: usize, seed: u64) -> RunReport {
        quick_run_threads(queue_depth, seed, 1)
    }

    #[test]
    fn pipelined_converges_like_multi() {
        let r = quick_run(2, 9);
        assert!(r.units > 10, "{} units", r.units);
        assert!(r.discarded > 0);
        assert!(r.signals >= 30_000);
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let a = quick_run(2, 4);
        let b = quick_run(2, 4);
        assert_eq!(a.units, b.units);
        assert_eq!(a.discarded, b.discarded);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn queue_depth_does_not_change_results() {
        // Backpressure depth affects timing only — the signal stream is a
        // pure function of the request sequence, which is deterministic.
        let a = quick_run(1, 7);
        let b = quick_run(4, 7);
        assert_eq!(a.units, b.units);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.discarded, b.discarded);
    }

    #[test]
    fn update_threads_do_not_change_pipelined_results() {
        // Prefetch composed with the pooled plan pass + concurrent commit:
        // report-level identity for every (queue_depth, update_threads)
        // pairing (network-level bit parity lives in
        // rust/tests/executor_parity.rs).
        let base = quick_run_threads(2, 7, 1);
        for (queue_depth, update_threads) in [(2usize, 3usize), (2, 0), (4, 2)] {
            let r = quick_run_threads(queue_depth, 7, update_threads);
            let label = format!("qd={queue_depth} upd={update_threads}");
            assert_eq!(base.units, r.units, "{label}");
            assert_eq!(base.iterations, r.iterations, "{label}");
            assert_eq!(base.discarded, r.discarded, "{label}");
            assert_eq!(base.qe.to_bits(), r.qe.to_bits(), "{label}");
        }
    }
}
