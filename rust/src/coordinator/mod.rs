//! Multi-signal coordination: the winner-lock table, the parallelism
//! schedule, and the pipelined driver.
//!
//! The paper's §2.2 collision taxonomy (adapt-position / modify-neighborhood
//! / insert-edge) is resolved by one mechanism — "an implicit lock on the
//! winner unit" — implemented here as [`LockTable`] and used by both
//! multi-signal drivers in [`crate::engine`].
//!
//! [`pipeline::run_pipelined`] is this reproduction's answer to the paper's
//! future-work note ("future developments … should aim to the
//! parallelization of the Update phase as well"): while the Update phase of
//! batch *k* runs, a sampler thread prefetches the signals of batch *k+1*
//! through a bounded (backpressure) channel, overlapping the Sample phase
//! entirely with Update.

pub mod locks;
pub mod pipeline;
pub mod schedule;

pub use locks::LockTable;
pub use pipeline::run_pipelined;
pub use schedule::MSchedule;
