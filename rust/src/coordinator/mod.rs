//! Multi-signal coordination: the batch-update executor, the winner-lock
//! table, the parallelism schedule, and the pipelined driver.
//!
//! The paper's §2.2 collision taxonomy (adapt-position / modify-neighborhood
//! / insert-edge) is resolved by one mechanism — "an implicit lock on the
//! winner unit" — implemented here as [`LockTable`] and enforced by
//! [`executor::BatchExecutor`], the single Update-phase implementation that
//! every convergence driver in [`crate::engine`] (and
//! [`pipeline::run_pipelined`]) delegates to. The single-signal drivers are
//! the degenerate `m = 1` case of the same executor.
//!
//! Two drivers answer the paper's future-work note ("future developments …
//! should aim to the parallelization of the Update phase as well"):
//!
//! - [`pipeline::run_pipelined`] overlaps the Sample phase of batch *k+1*
//!   with the Update phase of batch *k* through a bounded (backpressure)
//!   channel of depth `queue_depth` — composed, since PR 3, with the same
//!   pooled Update split as the `Parallel` driver;
//! - the `Parallel` driver (executor with `update_threads > 1`) splits the
//!   Update phase itself into a sequential admission pass, a plan pass
//!   over conflict-disjoint winner neighborhoods and a **shard-local
//!   concurrent commit** of the planned network writes — both executed in
//!   work-stealing chunks on the run's persistent
//!   [`crate::runtime::WorkerPool`] (shared with `find_threads`
//!   Find-Winners sharding; no per-flush thread spawning) — then replays
//!   the shared scalars in admission order, bit-identical to the
//!   sequential driver by construction (see `executor` for the full
//!   four-pass discipline).
//!
//! With the run's region partition attached (`regions` knob,
//! [`executor::BatchExecutor::set_regions`]) the schedule additionally
//! becomes **region-aware**: conflict domains are tracked per spatial
//! region of [`crate::som::regions::RegionMap`] instead of per unit, and
//! signals landing in disjoint region neighborhoods flow through the plan
//! *and* the structural commit concurrently — insertion-only updates
//! allocate their unit sequentially at admission (identical slab ids) and
//! commit their edge work on the pool alongside the adapt plans, so
//! insertions no longer serialize the concurrent commit. The sequential
//! scalar replay stays global and sequential on purpose: it is the one
//! place every order-sensitive f32 accumulation (QE, errors, the merged
//! log) happens, which is what keeps any `(regions, update_threads,
//! find_threads, queue_depth)` combination bit-identical to `Multi`.

pub mod executor;
pub mod locks;
pub mod pipeline;
pub mod schedule;

pub use executor::{BatchExecutor, InsertedGuard};
pub use locks::LockTable;
pub use pipeline::run_pipelined;
pub use schedule::MSchedule;
