//! The parallelism schedule (paper §3.1): `m` = the least power of two
//! strictly greater than the current unit count, capped.
//!
//! Interplay with the region schedule: a larger `m` spreads the batch's
//! signals over more of the surface, so more of them land in pairwise
//! disjoint region neighborhoods — the deferral window the region-aware
//! executor exploits grows with the very batch size this schedule grows.

/// Batch-size schedule for the multi-signal drivers.
#[derive(Clone, Copy, Debug)]
pub struct MSchedule {
    /// Paper: "the maximum level of parallelism has been set to 8192".
    pub cap: usize,
    /// Lower bound (a batch of at least 2 keeps the drivers simple).
    pub floor: usize,
}

impl Default for MSchedule {
    fn default() -> Self {
        Self { cap: 8192, floor: 2 }
    }
}

impl MSchedule {
    pub fn new(cap: usize) -> Self {
        Self { cap, floor: 2 }
    }

    /// Batch size for a network of `units` live units.
    #[inline]
    pub fn m(&self, units: usize) -> usize {
        (units + 1)
            .next_power_of_two()
            .min(self.cap)
            .max(self.floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strictly_greater_power_of_two() {
        let s = MSchedule::default();
        assert_eq!(s.m(0), 2);
        assert_eq!(s.m(1), 2);
        assert_eq!(s.m(2), 4, "strictly greater than the unit count");
        assert_eq!(s.m(7), 8);
        assert_eq!(s.m(8), 16);
        assert_eq!(s.m(330), 512);
    }

    #[test]
    fn capped_at_8192_by_default() {
        let s = MSchedule::default();
        assert_eq!(s.m(8191), 8192);
        assert_eq!(s.m(8192), 8192);
        assert_eq!(s.m(15_638), 8192, "paper's heptoroid network");
    }

    #[test]
    fn custom_cap() {
        let s = MSchedule::new(1024);
        assert_eq!(s.m(5_000), 1024);
    }
}
