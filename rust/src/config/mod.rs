//! Run configuration: typed structs, per-mesh presets (the paper's tuned
//! insertion thresholds), a TOML-subset parser for config files, and
//! `--set key=value` override merging.

mod parse;
mod presets;

pub use parse::{parse_config_text, ConfigError, ConfigValue};

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::findwinners::FwIsa;
use crate::mesh::BenchmarkShape;
use crate::som::{GngParams, GwrParams, SoamParams};

/// Why `driver = "pjrt"` is refused at config level since PR 6: the
/// ROADMAP's "make pjrt real" decision went the CPU way (runtime-dispatched
/// explicit-SIMD Find Winners — see the `fw_isa` knob), and an accelerator
/// column that silently under-delivers is worse than a loud error.
/// Programmatic use (`Driver::Pjrt` built in code — parity tests, benches
/// with AOT artifacts) remains supported.
pub const PJRT_QUARANTINE: &str = "driver \"pjrt\" is quarantined: the PJRT/XLA \
     offload is not wired to the unified executor; use the hardware-limit CPU \
     path instead (multi/pipelined/parallel + the fw_isa knob). Programmatic \
     `Driver::Pjrt` (tests/benches with AOT artifacts) is unaffected";

/// The four experimental columns of the paper (§3.1) plus this
/// reproduction's two Update-phase drivers (the paper's named future work:
/// "the parallelization of the Update phase").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Driver {
    /// Reference single-signal implementation (exhaustive Find Winners).
    Single,
    /// Single-signal with the spatial hash index.
    Indexed,
    /// Multi-signal semantics, sequential batched execution in rust.
    Multi,
    /// Multi-signal with the batched Find Winners executed from the AOT
    /// artifact on the PJRT runtime (the paper's GPU-based column).
    Pjrt,
    /// Multi-signal with the Sample phase of batch k+1 prefetched on a
    /// sampler thread while batch k updates (`queue_depth` backpressure),
    /// composed with the same pooled Update split as [`Driver::Parallel`]
    /// (`update_threads`).
    Pipelined,
    /// Multi-signal with the Update phase split into a sequential admission
    /// pass, a multi-threaded plan pass over conflict-disjoint winner
    /// groups and a concurrent commit of their network writes
    /// (`update_threads` workers, deterministic by construction).
    Parallel,
}

impl Driver {
    pub const ALL: [Driver; 6] = [
        Driver::Single,
        Driver::Indexed,
        Driver::Multi,
        Driver::Pjrt,
        Driver::Pipelined,
        Driver::Parallel,
    ];

    /// The paper's four experimental columns (§3.1), in table order.
    pub const PAPER_COLUMNS: [Driver; 4] =
        [Driver::Single, Driver::Indexed, Driver::Multi, Driver::Pjrt];

    pub fn name(self) -> &'static str {
        match self {
            Driver::Single => "single",
            Driver::Indexed => "indexed",
            Driver::Multi => "multi",
            Driver::Pjrt => "pjrt",
            Driver::Pipelined => "pipelined",
            Driver::Parallel => "parallel",
        }
    }

    /// Paper column header this driver reproduces (the two Update-phase
    /// drivers are this reproduction's additions, not paper columns).
    pub fn paper_name(self) -> &'static str {
        match self {
            Driver::Single => "Single-signal",
            Driver::Indexed => "Indexed",
            Driver::Multi => "Multi-signal",
            Driver::Pjrt => "GPU-based",
            Driver::Pipelined => "Pipelined (ours)",
            Driver::Parallel => "Parallel (ours)",
        }
    }

    pub fn from_name(s: &str) -> Option<Driver> {
        match s {
            "single" => Some(Driver::Single),
            "indexed" => Some(Driver::Indexed),
            "multi" => Some(Driver::Multi),
            "pjrt" | "gpu" => Some(Driver::Pjrt),
            "pipelined" => Some(Driver::Pipelined),
            "parallel" => Some(Driver::Parallel),
            _ => None,
        }
    }

    /// [`Driver::from_name`] for *configuration surfaces* (config files,
    /// `--set`, `--driver`, fleet manifests): parses the same names but
    /// refuses the quarantined `pjrt` driver with [`PJRT_QUARANTINE`].
    /// `Ok(None)` means the name is unknown (callers keep their own
    /// unknown-name error with the expected-names list).
    pub fn from_config_name(s: &str) -> Result<Option<Driver>, String> {
        match Driver::from_name(s) {
            Some(Driver::Pjrt) => Err(PJRT_QUARANTINE.to_string()),
            other => Ok(other),
        }
    }

    /// Every name [`Driver::from_name`] accepts (keep in sync with the CLI
    /// help and the `driver` config-key error).
    pub const NAMES: &'static str = "single|indexed|multi|pjrt|pipelined|parallel";

    pub fn is_multi_signal(self) -> bool {
        !matches!(self, Driver::Single | Driver::Indexed)
    }
}

/// Which growing network to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Soam,
    Gwr,
    Gng,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Soam => "soam",
            Algorithm::Gwr => "gwr",
            Algorithm::Gng => "gng",
        }
    }

    pub fn from_name(s: &str) -> Option<Algorithm> {
        match s {
            "soam" => Some(Algorithm::Soam),
            "gwr" => Some(Algorithm::Gwr),
            "gng" => Some(Algorithm::Gng),
            _ => None,
        }
    }
}

/// Run limits and bookkeeping cadence.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Hard cap on processed signals (safety net; converging runs stop on
    /// the algorithm's own criterion).
    pub max_signals: u64,
    /// Signals between housekeeping/convergence scans (single-signal
    /// drivers; multi-signal drivers scan per iteration).
    pub check_interval: u64,
    /// Paper: "the maximum level of parallelism has been set to 8192".
    pub max_parallelism: usize,
    /// Record trace points at every housekeeping scan.
    pub trace: bool,
}

impl Default for Limits {
    fn default() -> Self {
        Self {
            max_signals: 500_000_000,
            check_interval: 1_000,
            max_parallelism: 8192,
            trace: false,
        }
    }
}

/// Full configuration of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algorithm: Algorithm,
    pub driver: Driver,
    pub shape: BenchmarkShape,
    pub seed: u64,
    /// Marching-grid resolution for the benchmark mesh (0 = shape default).
    pub mesh_resolution: u32,
    /// Index cube size for the `Indexed` driver (tuned for performance,
    /// §3.1 — autotuned by `presets` from the insertion threshold).
    pub index_cell: f32,
    /// Unit-tile length for `BatchRust`.
    pub batch_tile: usize,
    /// Sampler prefetch depth for the `Pipelined` driver (how many batches
    /// the sampler thread may run ahead; ≥ 1).
    pub queue_depth: usize,
    /// Worker threads for the Update plan pass + concurrent commit of the
    /// `Parallel` and `Pipelined` drivers (0 = auto-detect, 1 =
    /// sequential; results are identical for any value by construction).
    pub update_threads: usize,
    /// Worker shards for the batched Find Winners scan: `find2_batch`
    /// signals are split across the run's persistent worker pool (shared
    /// with the Update plan pass). 0 = auto-detect, 1 = sequential
    /// (default). Each signal is computed independently, so results are
    /// bit-identical for any value; only wall time changes. Applies to the
    /// drivers whose scan runs in `BatchRust` (multi/pipelined/parallel);
    /// the pjrt scan runs inside the XLA executable and ignores it.
    pub find_threads: usize,
    /// Find-Winners SIMD tier override (`fw_isa` knob): `None` = auto
    /// (the `MSGSN_FW_ISA` env request, else the widest tier the host
    /// supports), `Some(tier)` forces that tier — rejected at
    /// [`crate::engine::make_findwinners`] when the host cannot execute
    /// it. Every tier returns bit-identical results (property-tested), so
    /// this knob only moves wall time; the dispatch state is
    /// process-global (last-built run wins — harmless for the same
    /// reason).
    pub fw_isa: Option<FwIsa>,
    /// Spatial regions the bounding volume is partitioned into (target
    /// count; the grid rounds up to a near-isotropic factorization).
    /// `1` (default) disables the partition. With `> 1`, the batched Find
    /// Winners scans only each signal's region neighborhood (exact, with a
    /// global fallback) and the parallel executors run the region-aware
    /// admission/plan/commit schedule in which insertion-only structural
    /// updates commit concurrently. Results are bit-identical for any
    /// value; only wall time changes. Applies to the `BatchRust` drivers
    /// (multi/pipelined/parallel).
    pub regions: usize,
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Artifact flavor override (`pallas` / `scan`; None = manifest default).
    pub flavor: Option<String>,
    pub soam: SoamParams,
    pub gwr: GwrParams,
    pub gng: GngParams,
    pub limits: Limits,
}

impl RunConfig {
    /// The tuned per-mesh preset (paper §3.1: shared parameters fixed, only
    /// the insertion threshold tuned per mesh).
    pub fn preset(shape: BenchmarkShape) -> Self {
        presets::preset(shape)
    }

    /// Apply `key = value` overrides (`--set`, config files). Returns an
    /// error naming the key when unknown or ill-typed.
    pub fn apply(&mut self, key: &str, value: &ConfigValue) -> Result<(), ConfigError> {
        let num = || -> Result<f64, ConfigError> {
            value
                .as_f64()
                .ok_or_else(|| ConfigError::Type(key.to_string(), "number"))
        };
        let int = || -> Result<u64, ConfigError> {
            value
                .as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                .map(|v| v as u64)
                .ok_or_else(|| ConfigError::Type(key.to_string(), "integer"))
        };
        match key {
            "algorithm" => {
                self.algorithm = value
                    .as_str()
                    .and_then(Algorithm::from_name)
                    .ok_or_else(|| ConfigError::Type(key.into(), "soam|gwr|gng"))?;
            }
            "driver" => {
                let s = value
                    .as_str()
                    .ok_or_else(|| ConfigError::Type(key.into(), Driver::NAMES))?;
                self.driver = Driver::from_config_name(s)
                    .map_err(|why| ConfigError::Unsupported(key.into(), why))?
                    .ok_or_else(|| ConfigError::Type(key.into(), Driver::NAMES))?;
            }
            "fw_isa" => {
                let s = value
                    .as_str()
                    .ok_or_else(|| ConfigError::Type(key.into(), FwIsa::CONFIG_NAMES))?;
                self.fw_isa = if s == "auto" {
                    None
                } else {
                    Some(
                        FwIsa::from_name(s)
                            .ok_or_else(|| ConfigError::Type(key.into(), FwIsa::CONFIG_NAMES))?,
                    )
                };
            }
            "mesh" | "shape" => {
                self.shape = value
                    .as_str()
                    .and_then(BenchmarkShape::from_name)
                    .ok_or_else(|| ConfigError::Type(key.into(), "blob|eight|hand|heptoroid"))?;
            }
            "seed" => self.seed = int()?,
            "mesh_resolution" => self.mesh_resolution = int()? as u32,
            "index_cell" => self.index_cell = num()? as f32,
            "batch_tile" => self.batch_tile = int()? as usize,
            "queue_depth" => self.queue_depth = (int()? as usize).max(1),
            "update_threads" => self.update_threads = int()? as usize,
            "find_threads" => self.find_threads = int()? as usize,
            "regions" => self.regions = (int()? as usize).max(1),
            "artifacts_dir" => {
                self.artifacts_dir = value
                    .as_str()
                    .ok_or_else(|| ConfigError::Type(key.into(), "path"))?
                    .into();
            }
            "flavor" => {
                self.flavor = Some(
                    value
                        .as_str()
                        .ok_or_else(|| ConfigError::Type(key.into(), "pallas|scan"))?
                        .to_string(),
                );
            }
            "insertion_threshold" => {
                let v = num()? as f32;
                self.soam.insertion_threshold = v;
                self.gwr.insertion_threshold = v;
            }
            "eps_b" => {
                let v = num()? as f32;
                self.soam.adapt.eps_b = v;
                self.gwr.adapt.eps_b = v;
                self.gng.adapt.eps_b = v;
            }
            "eps_n" => {
                let v = num()? as f32;
                self.soam.adapt.eps_n = v;
                self.gwr.adapt.eps_n = v;
                self.gng.adapt.eps_n = v;
            }
            "max_age" => {
                let v = num()? as f32;
                self.soam.adapt.max_age = v;
                self.gwr.adapt.max_age = v;
                self.gng.adapt.max_age = v;
            }
            "max_units" => {
                let v = int()? as usize;
                self.soam.max_units = v;
                self.gwr.max_units = v;
                self.gng.max_units = v;
            }
            "threshold_decay" => self.soam.threshold_decay = num()? as f32,
            "threshold_floor_frac" => self.soam.threshold_floor_frac = num()? as f32,
            "gng_lambda" => self.gng.lambda = int()?,
            "target_qe" => {
                let v = num()? as f32;
                self.gwr.target_qe = v;
                self.gng.target_qe = v;
            }
            "max_signals" => self.limits.max_signals = int()?,
            "check_interval" => self.limits.check_interval = int()?.max(1),
            "max_parallelism" => self.limits.max_parallelism = int()? as usize,
            "trace" => {
                self.limits.trace = value
                    .as_bool()
                    .ok_or_else(|| ConfigError::Type(key.into(), "bool"))?;
            }
            _ => return Err(ConfigError::UnknownKey(key.to_string())),
        }
        Ok(())
    }

    /// Apply a parsed config-file map (sorted for determinism).
    pub fn apply_all(
        &mut self,
        map: &BTreeMap<String, ConfigValue>,
    ) -> Result<(), ConfigError> {
        for (k, v) in map {
            self.apply(k, v)?;
        }
        Ok(())
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        Self::preset(BenchmarkShape::Blob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_all_shapes() {
        for shape in BenchmarkShape::ALL {
            let cfg = RunConfig::preset(shape);
            assert_eq!(cfg.shape, shape);
            assert!(cfg.soam.insertion_threshold > 0.0);
        }
    }

    #[test]
    fn thresholds_decrease_with_complexity() {
        // More complex meshes need more units ⇒ smaller thresholds
        // (paper: unit counts 347 < 658 < 8884 < 15638).
        let t: Vec<f32> = BenchmarkShape::ALL
            .iter()
            .map(|&s| RunConfig::preset(s).soam.insertion_threshold)
            .collect();
        assert!(t[0] > t[1] && t[1] > t[2] && t[2] >= t[3], "{t:?}");
    }

    #[test]
    fn apply_overrides() {
        let mut cfg = RunConfig::default();
        cfg.apply("driver", &ConfigValue::Str("multi".into())).unwrap();
        assert_eq!(cfg.driver, Driver::Multi);
        cfg.apply("insertion_threshold", &ConfigValue::Num(0.123)).unwrap();
        assert!((cfg.soam.insertion_threshold - 0.123).abs() < 1e-6);
        cfg.apply("seed", &ConfigValue::Num(9.0)).unwrap();
        assert_eq!(cfg.seed, 9);
        cfg.apply("trace", &ConfigValue::Bool(true)).unwrap();
        assert!(cfg.limits.trace);
    }

    #[test]
    fn pjrt_driver_quarantined_at_config_level() {
        // Acceptance (PR 6): `driver = "pjrt"` fails loudly at parse time
        // instead of silently degrading — from every config surface that
        // funnels through `apply`/`from_config_name` (config files, --set,
        // --driver, fleet manifests).
        let mut cfg = RunConfig::default();
        let before = cfg.driver;
        for name in ["pjrt", "gpu"] {
            let err = cfg.apply("driver", &ConfigValue::Str(name.into())).unwrap_err();
            match &err {
                ConfigError::Unsupported(key, why) => {
                    assert_eq!(key, "driver");
                    assert!(why.contains("not wired to the unified executor"), "{why}");
                }
                other => panic!("expected Unsupported, got {other:?}"),
            }
            assert!(err.to_string().contains("quarantined"), "{err}");
            assert_eq!(cfg.driver, before, "failed apply must not change the config");
        }
        // Programmatic use keeps parsing (parity tests, benches).
        assert_eq!(Driver::from_name("pjrt"), Some(Driver::Pjrt));
        // Unknown names still get the expected-names Type error.
        assert!(matches!(
            cfg.apply("driver", &ConfigValue::Str("warp".into())),
            Err(ConfigError::Type(_, _))
        ));
    }

    #[test]
    fn fw_isa_knob_applies() {
        use crate::findwinners::FwIsa;
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.fw_isa, None, "default is auto-dispatch");
        cfg.apply("fw_isa", &ConfigValue::Str("fallback".into())).unwrap();
        assert_eq!(cfg.fw_isa, Some(FwIsa::Fallback));
        cfg.apply("fw_isa", &ConfigValue::Str("avx512".into())).unwrap();
        assert_eq!(cfg.fw_isa, Some(FwIsa::Avx512), "parse-time accepts any tier");
        cfg.apply("fw_isa", &ConfigValue::Str("auto".into())).unwrap();
        assert_eq!(cfg.fw_isa, None, "auto resets to dispatch");
        assert!(matches!(
            cfg.apply("fw_isa", &ConfigValue::Str("sse9".into())),
            Err(ConfigError::Type(_, FwIsa::CONFIG_NAMES))
        ));
        assert!(matches!(
            cfg.apply("fw_isa", &ConfigValue::Num(2.0)),
            Err(ConfigError::Type(_, _))
        ));
    }

    #[test]
    fn apply_rejects_unknown_and_ill_typed() {
        let mut cfg = RunConfig::default();
        assert!(matches!(
            cfg.apply("nonesuch", &ConfigValue::Num(1.0)),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(matches!(
            cfg.apply("seed", &ConfigValue::Str("x".into())),
            Err(ConfigError::Type(_, _))
        ));
        assert!(matches!(
            cfg.apply("seed", &ConfigValue::Num(1.5)),
            Err(ConfigError::Type(_, _))
        ));
    }

    #[test]
    fn driver_names_roundtrip() {
        for d in Driver::ALL {
            assert_eq!(Driver::from_name(d.name()), Some(d));
        }
        assert_eq!(Driver::from_name("gpu"), Some(Driver::Pjrt));
    }

    #[test]
    fn every_advertised_driver_name_parses() {
        // The CLI help, `Driver::NAMES` and `from_name` must agree — the
        // help once advertised `pipelined` while `from_name` rejected it.
        for name in Driver::NAMES.split('|') {
            let d = Driver::from_name(name)
                .unwrap_or_else(|| panic!("advertised driver {name:?} does not parse"));
            assert_eq!(d.name(), name);
        }
        assert_eq!(Driver::NAMES.split('|').count(), Driver::ALL.len());
    }

    #[test]
    fn multi_signal_split_covers_all_drivers() {
        // Only the two basic-iteration drivers are single-signal; the
        // paper columns are the first four of ALL.
        for d in Driver::ALL {
            let expect = !matches!(d, Driver::Single | Driver::Indexed);
            assert_eq!(d.is_multi_signal(), expect, "{}", d.name());
        }
        assert_eq!(&Driver::ALL[..4], &Driver::PAPER_COLUMNS);
    }

    #[test]
    fn update_phase_driver_knobs_apply() {
        let mut cfg = RunConfig::default();
        cfg.apply("driver", &ConfigValue::Str("pipelined".into())).unwrap();
        assert_eq!(cfg.driver, Driver::Pipelined);
        cfg.apply("driver", &ConfigValue::Str("parallel".into())).unwrap();
        assert_eq!(cfg.driver, Driver::Parallel);
        cfg.apply("queue_depth", &ConfigValue::Num(4.0)).unwrap();
        assert_eq!(cfg.queue_depth, 4);
        cfg.apply("queue_depth", &ConfigValue::Num(0.0)).unwrap();
        assert_eq!(cfg.queue_depth, 1, "depth clamps to >= 1");
        cfg.apply("update_threads", &ConfigValue::Num(8.0)).unwrap();
        assert_eq!(cfg.update_threads, 8);
        assert_eq!(cfg.find_threads, 1, "sharded find is opt-in");
        cfg.apply("find_threads", &ConfigValue::Num(4.0)).unwrap();
        assert_eq!(cfg.find_threads, 4);
        cfg.apply("find_threads", &ConfigValue::Num(0.0)).unwrap();
        assert_eq!(cfg.find_threads, 0, "0 = auto-detect");
        assert!(matches!(
            cfg.apply("find_threads", &ConfigValue::Num(1.5)),
            Err(ConfigError::Type(_, _))
        ));
        assert_eq!(cfg.regions, 1, "region partition is opt-in");
        cfg.apply("regions", &ConfigValue::Num(64.0)).unwrap();
        assert_eq!(cfg.regions, 64);
        cfg.apply("regions", &ConfigValue::Num(0.0)).unwrap();
        assert_eq!(cfg.regions, 1, "regions clamp to >= 1");
        assert!(matches!(
            cfg.apply("regions", &ConfigValue::Num(2.5)),
            Err(ConfigError::Type(_, _))
        ));
    }
}
