//! A TOML-subset parser (the vendored crate set has no `toml`/`serde`).
//!
//! Supported grammar — deliberately the subset real run configs need:
//!
//! ```toml
//! # comment
//! key = 1.5            # number
//! name = "multi"       # string (double quotes)
//! flag = true          # bool
//! [section]            # keys below become "section.key" …
//! inner = 2            # … except the conventional [run] section, which is
//!                      # flattened (its keys are top-level RunConfig keys).
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// Parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl ConfigValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ConfigValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            ConfigValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ConfigValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Configuration errors (parse + apply).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// `(line, message)`
    Parse(usize, String),
    UnknownKey(String),
    /// `(key, expected type)`
    Type(String, &'static str),
    /// `(key, reason)` — the value parses but names a feature this build
    /// deliberately refuses at config level (e.g. the quarantined `pjrt`
    /// driver).
    Unsupported(String, String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Parse(line, msg) => write!(f, "config line {line}: {msg}"),
            ConfigError::UnknownKey(k) => write!(f, "unknown config key {k:?}"),
            ConfigError::Type(k, want) => write!(f, "config key {k:?} expects {want}"),
            ConfigError::Unsupported(k, why) => write!(f, "config key {k:?}: {why}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse config text into a flat `key -> value` map. Keys inside a
/// `[section]` other than `[run]` are prefixed `section.`.
pub fn parse_config_text(text: &str) -> Result<BTreeMap<String, ConfigValue>, ConfigError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (n, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::Parse(n + 1, "unterminated section".into()))?
                .trim();
            if name.is_empty() {
                return Err(ConfigError::Parse(n + 1, "empty section name".into()));
            }
            section = if name == "run" { String::new() } else { format!("{name}.") };
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| ConfigError::Parse(n + 1, format!("expected key = value, got {line:?}")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ConfigError::Parse(n + 1, "empty key".into()));
        }
        let value = parse_value(line[eq + 1..].trim())
            .ok_or_else(|| ConfigError::Parse(n + 1, format!("bad value in {line:?}")))?;
        map.insert(format!("{section}{key}"), value);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<ConfigValue> {
    if s == "true" {
        return Some(ConfigValue::Bool(true));
    }
    if s == "false" {
        return Some(ConfigValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        if inner.contains('"') {
            return None;
        }
        return Some(ConfigValue::Str(inner.to_string()));
    }
    // Underscored integers (1_000_000) as in TOML.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned.parse::<f64>().ok().map(ConfigValue::Num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let m = parse_config_text(
            "a = 1\nb = 2.5\nc = \"hello\"\nd = true\ne = 1_000\n",
        )
        .unwrap();
        assert_eq!(m["a"], ConfigValue::Num(1.0));
        assert_eq!(m["b"], ConfigValue::Num(2.5));
        assert_eq!(m["c"], ConfigValue::Str("hello".into()));
        assert_eq!(m["d"], ConfigValue::Bool(true));
        assert_eq!(m["e"], ConfigValue::Num(1000.0));
    }

    #[test]
    fn sections_prefix_keys_except_run() {
        let m = parse_config_text("[run]\nseed = 1\n[soam]\nx = 2\n").unwrap();
        assert!(m.contains_key("seed"));
        assert!(m.contains_key("soam.x"));
    }

    #[test]
    fn comments_ignored_even_after_values() {
        let m = parse_config_text("a = 1 # one\n# whole line\nb = \"x # y\"\n").unwrap();
        assert_eq!(m["a"], ConfigValue::Num(1.0));
        assert_eq!(m["b"], ConfigValue::Str("x # y".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_config_text("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(err, ConfigError::Parse(2, "expected key = value, got \"broken\"".into()));
        assert!(parse_config_text("[oops\n").is_err());
        assert!(parse_config_text("a = \"unterminated\n").is_err());
    }

    #[test]
    fn display_formats() {
        let e = ConfigError::Type("seed".into(), "integer");
        assert_eq!(e.to_string(), "config key \"seed\" expects integer");
    }
}
