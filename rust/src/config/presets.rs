//! Per-mesh presets, following the paper's protocol (§3.1): every shared
//! parameter identical across meshes; only the insertion threshold tuned
//! per mesh (it controls the final unit count and must track each mesh's
//! feature scale); the index cube size derived from the threshold (the
//! paper tuned it "specifically for maximum performances" — `index_cell ≈
//! 2·threshold` keeps the expected 27-cell population small but nonempty).

use std::path::PathBuf;

use crate::mesh::BenchmarkShape;
use crate::som::{GngParams, GwrParams, SoamParams};

use super::{Algorithm, Driver, Limits, RunConfig};

/// Tuned insertion threshold per mesh (unit-cube-normalized coordinates).
///
/// Calibrated so the converged SOAM network lands in the same size regime
/// as the paper's Tables 1–4 (347 / 658 / 8,884 / 15,638 units): unit
/// spacing scales like `sqrt(area / units)`.
pub fn insertion_threshold(shape: BenchmarkShape) -> f32 {
    // Calibration: units ≈ 0.8·area/th² (correction factor measured on the
    // blob: threshold 0.084 converged at 277 units), so
    // th = sqrt(0.8·area / units_target). Areas of the unit-cube-normalized
    // proxy meshes: blob 2.45, eight 1.10, hand 2.69, heptoroid 0.87.
    match shape {
        BenchmarkShape::Blob => 0.0752,      // target ≈ 347 units (Table 1)
        BenchmarkShape::Eight => 0.0365,     // target ≈ 658 units (Table 2)
        BenchmarkShape::Hand => 0.0156,      // target ≈ 8,884 units (Table 3)
        BenchmarkShape::Heptoroid => 0.0067, // target ≈ 15,638 units (Table 4)
    }
}

/// Full tuned configuration for one benchmark mesh.
pub fn preset(shape: BenchmarkShape) -> RunConfig {
    let threshold = insertion_threshold(shape);
    let mut soam = SoamParams::default();
    soam.insertion_threshold = threshold;
    let mut gwr = GwrParams::default();
    gwr.insertion_threshold = threshold;
    let gng = GngParams::default();
    RunConfig {
        algorithm: Algorithm::Soam,
        driver: Driver::Single,
        shape,
        seed: 42,
        mesh_resolution: 0, // shape default
        index_cell: (2.0 * threshold).clamp(0.02, 0.25),
        batch_tile: 512,
        queue_depth: 2,
        update_threads: 0, // auto-detect
        // Sharded Find Winners is opt-in (`--set find_threads=N|0`): the
        // paper's Multi column is explicitly "without any actual
        // parallelization", so the default keeps that semantics-preserving
        // baseline single-threaded.
        find_threads: 1,
        // Auto-dispatch the widest supported SIMD Find-Winners tier
        // (`--set fw_isa=fallback|avx2|avx512|neon` forces one; every tier
        // is bit-identical, so this only moves wall time).
        fw_isa: None,
        // The spatial region partition is likewise opt-in
        // (`--set regions=R`): results are bit-identical either way, and
        // the paper's columns have no region decomposition.
        regions: 1,
        artifacts_dir: PathBuf::from("artifacts"),
        flavor: None,
        soam,
        gwr,
        gng,
        limits: Limits::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_cell_tracks_threshold() {
        for shape in BenchmarkShape::ALL {
            let cfg = preset(shape);
            let t = cfg.soam.insertion_threshold;
            assert!(cfg.index_cell >= t, "cell must cover a unit spacing");
        }
    }

    #[test]
    fn shared_params_identical_across_meshes() {
        // The paper keeps every parameter but the insertion threshold fixed.
        let base = preset(BenchmarkShape::Blob);
        for shape in BenchmarkShape::ALL {
            let cfg = preset(shape);
            assert_eq!(cfg.soam.adapt.eps_b, base.soam.adapt.eps_b);
            assert_eq!(cfg.soam.adapt.eps_n, base.soam.adapt.eps_n);
            assert_eq!(cfg.soam.adapt.max_age, base.soam.adapt.max_age);
            assert_eq!(cfg.soam.hab.threshold, base.soam.hab.threshold);
            assert_eq!(cfg.limits.max_parallelism, 8192);
        }
    }
}
