//! Minimal property-testing harness (the vendored crate set has no
//! `proptest`/`quickcheck`).
//!
//! Deterministic: case `k` of a run with seed `s` always sees the RNG stream
//! `SplitMix64(s).nth(k)`, so a failure message's `(seed, case)` pair
//! reproduces exactly. No automatic shrinking — generators are expected to
//! draw *sized* inputs (`sized_usize`) so early cases are small, which gives
//! most of shrinking's benefit for these invariants.

use crate::rng::{Rng, SplitMix64};

/// Property runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Prop {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC0FFEE }
    }
}

impl Prop {
    pub fn new(cases: u32, seed: u64) -> Self {
        Self { cases, seed }
    }

    /// Run `property` on `cases` generated inputs; panics with the
    /// reproducing `(seed, case)` on the first counterexample.
    ///
    /// The generator receives `(rng, size)` where `size` ramps 0 → 100 over
    /// the run, so early failures are small.
    pub fn run<T: std::fmt::Debug>(
        &self,
        generate: impl Fn(&mut Rng, u32) -> T,
        property: impl Fn(&T) -> Result<(), String>,
    ) {
        let mut seeder = SplitMix64::new(self.seed);
        for case in 0..self.cases {
            let case_seed = seeder.next_u64();
            let mut rng = Rng::seed_from(case_seed);
            let size = if self.cases <= 1 { 100 } else { 100 * case / (self.cases - 1) };
            let input = generate(&mut rng, size);
            if let Err(msg) = property(&input) {
                panic!(
                    "property failed (seed={:#x}, case={case}, case_seed={case_seed:#x}):\n  \
                     {msg}\n  input: {input:?}",
                    self.seed
                );
            }
        }
    }
}

/// Draw a usize in `[lo, hi]` scaled by the size ramp (small early).
pub fn sized_usize(rng: &mut Rng, size: u32, lo: usize, hi: usize) -> usize {
    let span = hi.saturating_sub(lo);
    let cap = lo + span * (size as usize).min(100) / 100;
    if cap <= lo {
        lo
    } else {
        lo + rng.index(cap - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::default().run(
            |rng, size| sized_usize(rng, size, 0, 1000),
            |&x| {
                if x <= 1000 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_counterexample() {
        Prop::new(50, 7).run(
            |rng, size| sized_usize(rng, size, 0, 100),
            |&x| {
                if x < 90 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn sizes_ramp_up() {
        let mut small = Vec::new();
        let mut rng = Rng::seed_from(0);
        for size in [0, 50, 100] {
            small.push(sized_usize(&mut rng, size, 1, 101));
        }
        // size=0 pins to the lower bound.
        assert_eq!(small[0], 1);
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        use std::cell::RefCell;
        let seen_a = RefCell::new(Vec::new());
        Prop::new(10, 42).run(
            |rng, _| rng.next_u64(),
            |&x| {
                seen_a.borrow_mut().push(x);
                Ok(())
            },
        );
        let seen_b = RefCell::new(Vec::new());
        Prop::new(10, 42).run(
            |rng, _| rng.next_u64(),
            |&x| {
                seen_b.borrow_mut().push(x);
                Ok(())
            },
        );
        assert_eq!(seen_a.into_inner(), seen_b.into_inner());
    }
}
