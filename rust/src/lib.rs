//! # msgsn — Multi-Signal Growing Self-Organizing Networks
//!
//! A reproduction of *"A Multi-signal Variant for the GPU-based
//! Parallelization of Growing Self-Organizing Networks"* (Parigi, Stramieri,
//! Pau, Piastra; 2015) as a three-layer rust + JAX + Pallas system:
//!
//! - **Layer 1/2** (build time, `python/compile/`): the batched top-2
//!   nearest-unit search ("Find Winners") as a Pallas kernel wrapped in a JAX
//!   graph, AOT-lowered per size bucket to HLO text under `artifacts/`.
//! - **Layer 3** (this crate): everything else — the growing-network
//!   framework (GNG / GWR / SOAM), the multi-signal batcher with its
//!   winner-lock collision rule, the spatial hash index, the mesh substrate
//!   (implicit surfaces → marching tetrahedra → area-weighted point-cloud
//!   sampling), the PJRT runtime that executes the AOT artifacts, and the
//!   benchmark harness that regenerates every table and figure of the paper.
//!
//! Python never runs after `make artifacts`; the `msgsn` binary is
//! self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`rng`] | deterministic SplitMix64 / Xoshiro256** PRNG streams |
//! | [`geometry`] | `Vec3`, `Aabb`, triangle primitives |
//! | [`implicit`] | implicit scalar fields + CSG, the four benchmark shapes |
//! | [`marching`] | marching-tetrahedra polygonizer (watertight by construction) |
//! | [`mesh`] | indexed triangle meshes, IO, Euler/genus stats, samplers |
//! | [`topology`] | neighborhood-graph classification (disk / half-disk / …) |
//! | [`som`] | network store + GNG / GWR / SOAM update rules |
//! | [`index`] | uniform spatial hash grid (the paper's *Indexed* variant) |
//! | [`findwinners`] | `FindWinners` trait: scalar / indexed / batched impls |
//! | [`runtime`] | PJRT client + AOT artifact registry (the *GPU-based* variant) |
//! | [`coordinator`] | batch-update executor, m-schedule, winner locks, pipeline |
//! | [`engine`] | convergence drivers + resumable [`engine::ConvergenceSession`]s |
//! | [`fleet`] | multi-network orchestration: jobs manifest, shared-pool scheduler, bit-exact checkpoint/restore |
//! | [`dist`] | fault-tolerant multi-process fleet: coordinator/worker split, heartbeats, partition-safe job migration over snapshot bytes |
//! | [`serve`] | the fleet as a long-running service: line-JSON protocol over TCP, QoS scheduling, batch-boundary read views |
//! | [`config`] | config structs, TOML-subset parser, per-mesh presets |
//! | [`cli`] | argument parsing for the `msgsn` binary |
//! | [`metrics`] | phase timers, counters, table rendering |
//! | [`telemetry`] | lock-free instrument registry + structured event trace, JSON/Prometheus exposition |
//! | [`bench`] | experiment grid regenerating Tables 1–4 and Figs 2,7–10 |
//! | [`proptest`] | minimal in-repo property-testing harness |

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod engine;
pub mod findwinners;
pub mod fleet;
pub mod geometry;
pub mod implicit;
pub mod index;
pub mod marching;
pub mod mesh;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod som;
pub mod telemetry;
pub mod topology;

/// The most common imports, bundled.
pub mod prelude {
    pub use crate::geometry::{Aabb, Vec3};
    pub use crate::mesh::{BenchmarkShape, Mesh};
    pub use crate::rng::Rng;
}
