//! Replication invariant (paper §3.1): "multi-signal and GPU-based
//! implementations reach exactly the same final configuration, since they
//! are meant to replicate the same behavior by design".
//!
//! Our `Multi` (BatchRust) and `Pjrt` drivers share every line of driver
//! code and every RNG draw; the only difference is who computes the batched
//! top-2. XLA's FMA contraction can shift distances by ~1 ulp, which could
//! in principle flip a winner on a near-exact tie; these tests verify that
//! on real workloads with fixed seeds the final configurations coincide
//! exactly, and that the multi driver itself is deterministic.
//!
//! Requires `make artifacts` (PJRT tests skip otherwise).

use std::path::Path;

use msgsn::config::{Driver, RunConfig};
use msgsn::engine::run;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape};
use msgsn::rng::Rng;

fn artifacts_ready() -> bool {
    let ok = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn small_cfg(shape: BenchmarkShape, max_signals: u64) -> RunConfig {
    let mut cfg = RunConfig::preset(shape);
    cfg.soam.insertion_threshold = 0.16;
    cfg.gwr.insertion_threshold = 0.16;
    cfg.limits.max_signals = max_signals;
    cfg.artifacts_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg
}

#[test]
fn multi_and_pjrt_reach_same_final_configuration() {
    if !artifacts_ready() {
        return;
    }
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
    let cfg = small_cfg(BenchmarkShape::Blob, 60_000);
    let mut rng_a = Rng::seed_from(11);
    let mut rng_b = Rng::seed_from(11);
    let a = run(&mesh, Driver::Multi, &cfg, &mut rng_a).unwrap();
    let b = run(&mesh, Driver::Pjrt, &cfg, &mut rng_b).unwrap();
    assert_eq!(a.iterations, b.iterations, "iteration counts diverge");
    assert_eq!(a.signals, b.signals);
    assert_eq!(a.discarded, b.discarded, "winner-lock decisions diverge");
    assert_eq!(a.units, b.units, "unit counts diverge");
    assert_eq!(a.connections, b.connections, "edge counts diverge");
    assert_eq!(a.converged, b.converged);
}

#[test]
fn parity_holds_across_seeds_and_meshes() {
    if !artifacts_ready() {
        return;
    }
    for (shape, seed) in [
        (BenchmarkShape::Blob, 1u64),
        (BenchmarkShape::Eight, 2u64),
    ] {
        let mesh = benchmark_mesh(shape, 20);
        let cfg = small_cfg(shape, 25_000);
        let mut rng_a = Rng::seed_from(seed);
        let mut rng_b = Rng::seed_from(seed);
        let a = run(&mesh, Driver::Multi, &cfg, &mut rng_a).unwrap();
        let b = run(&mesh, Driver::Pjrt, &cfg, &mut rng_b).unwrap();
        assert_eq!(
            (a.units, a.connections, a.discarded),
            (b.units, b.connections, b.discarded),
            "{shape:?} seed {seed}"
        );
    }
}

#[test]
fn multi_driver_is_deterministic() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
    let cfg = small_cfg(BenchmarkShape::Blob, 40_000);
    let mut r1 = Rng::seed_from(5);
    let mut r2 = Rng::seed_from(5);
    let a = run(&mesh, Driver::Multi, &cfg, &mut r1).unwrap();
    let b = run(&mesh, Driver::Multi, &cfg, &mut r2).unwrap();
    assert_eq!(a.units, b.units);
    assert_eq!(a.connections, b.connections);
    assert_eq!(a.discarded, b.discarded);
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn single_and_multi_same_seed_differ_but_same_regime() {
    // The paper's behavioral finding: the multi-signal variant is a
    // *different* algorithm (it needs fewer effective signals) yet lands in
    // the same configuration regime (±50% units here; Tables 1–4 show
    // 330→347, 656→658, 5669→8884, 14183→15638 across the real meshes).
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
    let cfg = small_cfg(BenchmarkShape::Blob, 120_000);
    let mut r1 = Rng::seed_from(3);
    let mut r2 = Rng::seed_from(3);
    let a = run(&mesh, Driver::Single, &cfg, &mut r1).unwrap();
    let b = run(&mesh, Driver::Multi, &cfg, &mut r2).unwrap();
    let ratio = a.units as f64 / b.units as f64;
    assert!((0.5..=2.0).contains(&ratio), "{} vs {}", a.units, b.units);
    assert!(b.discarded > 0);
}
