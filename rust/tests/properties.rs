//! Property tests over the DESIGN.md §7 invariants, using the in-repo
//! mini property harness (`msgsn::proptest`).

use msgsn::coordinator::{LockTable, MSchedule};
use msgsn::findwinners::{BatchRust, FindWinners, Indexed, Scalar};
use msgsn::geometry::Vec3;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use msgsn::proptest::{sized_usize, Prop};
use msgsn::rng::Rng;
use msgsn::som::{ChangeLog, GrowingNetwork, Network, Soam, SoamParams, Winners};

fn random_net(rng: &mut Rng, n: usize) -> Network {
    let mut net = Network::new();
    for _ in 0..n {
        net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1);
    }
    net
}

/// §7.1 — the m-schedule: least power of two strictly above the unit count,
/// capped, for every unit count.
#[test]
fn prop_m_schedule() {
    Prop::new(300, 1).run(
        |rng, size| sized_usize(rng, size, 0, 100_000),
        |&units| {
            let m = MSchedule::default().m(units);
            if !m.is_power_of_two() {
                return Err(format!("m={m} not a power of two"));
            }
            if units < 8192 && m <= units {
                return Err(format!("m={m} not strictly greater than {units}"));
            }
            if m > 8192 {
                return Err(format!("m={m} exceeds the 8192 cap"));
            }
            Ok(())
        },
    );
}

/// §7.2 — within a batch no two applied signals share a winner.
#[test]
fn prop_lock_table_excludes_duplicates() {
    Prop::new(100, 2).run(
        |rng, size| {
            let n = sized_usize(rng, size, 1, 500);
            let winners: Vec<u32> = (0..n).map(|_| rng.below(50) as u32).collect();
            winners
        },
        |winners| {
            let mut locks = LockTable::new();
            locks.next_batch();
            let mut applied = Vec::new();
            for &w in winners {
                if locks.try_lock(w) {
                    applied.push(w);
                }
            }
            let mut dedup = applied.clone();
            dedup.sort_unstable();
            dedup.dedup();
            if dedup.len() != applied.len() {
                return Err("two applied signals share a winner".into());
            }
            // Every distinct winner is applied exactly once.
            let mut distinct: Vec<u32> = winners.clone();
            distinct.sort_unstable();
            distinct.dedup();
            if distinct.len() != applied.len() {
                return Err(format!(
                    "{} distinct winners but {} applied",
                    distinct.len(),
                    applied.len()
                ));
            }
            Ok(())
        },
    );
}

/// §7.5 — every exact Find-Winners implementation agrees with Scalar.
#[test]
fn prop_findwinners_agreement() {
    Prop::new(60, 3).run(
        |rng, size| {
            let units = sized_usize(rng, size, 2, 400);
            let signals = sized_usize(rng, size, 1, 100);
            let net = random_net(rng, units);
            let sigs: Vec<Vec3> = (0..signals)
                .map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32()))
                .collect();
            (net, sigs)
        },
        |(net, sigs)| {
            let mut scalar = Scalar::new();
            let mut batch = BatchRust::new(64);
            let mut got = Vec::new();
            batch.find2_batch(net, sigs, &mut got);
            for (j, s) in sigs.iter().enumerate() {
                let want = scalar.find2(net, *s);
                if got[j] != want {
                    return Err(format!("batch disagrees at {j}: {:?} vs {want:?}", got[j]));
                }
            }
            Ok(())
        },
    );
}

/// The Indexed variant is approximate, but its reported distance can never
/// beat the true minimum, and its fallback path is exact.
#[test]
fn prop_indexed_never_beats_exhaustive() {
    Prop::new(40, 4).run(
        |rng, size| {
            let units = sized_usize(rng, size, 2, 300);
            let net = random_net(rng, units);
            let sigs: Vec<Vec3> = (0..20)
                .map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32()))
                .collect();
            (net, sigs)
        },
        |(net, sigs)| {
            let mut idx = Indexed::new(0.12);
            idx.rebuild(net);
            let mut scalar = Scalar::new();
            for s in sigs {
                let a = idx.find2(net, *s).unwrap();
                let b = scalar.find2(net, *s).unwrap();
                if a.d1_sq + 1e-9 < b.d1_sq {
                    return Err(format!("indexed {a:?} beats exhaustive {b:?}"));
                }
                if a.w1 == a.w2 {
                    return Err("winner == second".into());
                }
            }
            Ok(())
        },
    );
}

/// §7.6 — network structural invariants hold after arbitrary random update
/// streams through the real SOAM rule (including stale winners).
#[test]
fn prop_network_invariants_under_soam_updates() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 16);
    let sampler = SurfaceSampler::new(&mesh);
    Prop::new(25, 5).run(
        |rng, size| {
            let steps = sized_usize(rng, size, 10, 3_000);
            (rng.next_u64(), steps)
        },
        |&(seed, steps)| {
            let mut rng = Rng::seed_from(seed);
            let mut soam = Soam::new(SoamParams {
                insertion_threshold: 0.15,
                ..SoamParams::default()
            });
            soam.init(&sampler, &mut rng);
            let mut fw = Scalar::new();
            let mut log = ChangeLog::default();
            for k in 0..steps {
                let s = sampler.sample(&mut rng);
                let mut w = fw.find2(soam.net(), s).unwrap();
                // Occasionally feed stale/garbage winners — they must be
                // ignored, never corrupt the store.
                if k % 97 == 13 {
                    w = Winners { w1: 9_999_999, w2: w.w2, d1_sq: 0.0, d2_sq: 0.1 };
                }
                log.clear();
                soam.update(s, &w, &mut log);
                // Moved/inserted/removed ids must reference real slots.
                for &(id, _) in &log.moved {
                    if !soam.net().is_alive(id) && !log.removed.iter().any(|&(r, _)| r == id) {
                        return Err(format!("moved id {id} neither alive nor removed"));
                    }
                }
            }
            log.clear();
            soam.housekeeping(&mut log);
            soam.net().check_invariants().map_err(|e| format!("after {steps} steps: {e}"))
        },
    );
}

/// §7.3 — applied + discarded = m for every batch (checked through the
/// public driver on varying caps).
#[test]
fn prop_signal_accounting() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 16);
    Prop::new(10, 6).run(
        |rng, size| {
            let cap = sized_usize(rng, size, 1_000, 40_000) as u64;
            (rng.next_u64(), cap)
        },
        |&(seed, cap)| {
            use msgsn::config::{Driver, RunConfig};
            let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
            cfg.soam.insertion_threshold = 0.2;
            cfg.limits.max_signals = cap;
            let mut rng = Rng::seed_from(seed);
            let r = msgsn::engine::run(&mesh, Driver::Multi, &cfg, &mut rng)
                .map_err(|e| e.to_string())?;
            if r.discarded > r.signals {
                return Err(format!("discarded {} > signals {}", r.discarded, r.signals));
            }
            if r.signals < cap {
                // Can only stop early by converging.
                if !r.converged {
                    return Err("stopped early without convergence".into());
                }
            }
            Ok(())
        },
    );
}

/// Sampler outputs always lie on the source surface (barycentric hull).
#[test]
fn prop_sampler_on_surface() {
    let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
    let sampler = SurfaceSampler::new(&mesh);
    let bounds = mesh.bounds().inflated(1e-4);
    Prop::new(50, 7).run(
        |rng, _| {
            let mut r2 = Rng::seed_from(rng.next_u64());
            sampler.sample(&mut r2)
        },
        |p| {
            if !p.is_finite() {
                return Err("non-finite sample".into());
            }
            if !bounds.contains(*p) {
                return Err(format!("sample {p:?} outside mesh bounds"));
            }
            Ok(())
        },
    );
}

/// One merged-`ChangeLog` sync must leave the `Indexed` grid identical to
/// syncing the same changes one signal at a time — the contract the
/// batch-update executor's single-sync-per-batch relies on. Ops include
/// the nasty merged cases: repeated moves, move-then-remove, and removal
/// followed by an insert that reuses the slab slot.
#[test]
fn prop_merged_sync_equals_per_signal_syncs() {
    use msgsn::som::Network as Net;

    // Probe the grid through its public query surface: the sorted id set
    // of each of a few dozen 27-cell neighborhoods.
    fn probe(idx: &Indexed, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::seed_from(seed);
        (0..40)
            .map(|_| {
                let p = Vec3::new(rng.f32(), rng.f32(), rng.f32());
                let mut ids = Vec::new();
                idx.grid().for_neighborhood(p, |id| ids.push(id));
                ids.sort_unstable();
                ids
            })
            .collect()
    }

    Prop::new(60, 8).run(
        |rng, size| {
            let units = sized_usize(rng, size, 3, 120);
            let ops = sized_usize(rng, size, 1, 60);
            (rng.next_u64(), units, ops)
        },
        |&(seed, units, ops)| {
            let mut rng = Rng::seed_from(seed);
            // Two identical nets evolve in lockstep; only the sync cadence
            // differs between the two indexes.
            let mut net_a = Net::new();
            for _ in 0..units {
                net_a.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1);
            }
            let mut net_b = net_a.clone();
            let mut idx_a = Indexed::new(0.11); // per-op syncs
            let mut idx_b = Indexed::new(0.11); // one merged sync
            idx_a.rebuild(&net_a);
            idx_b.rebuild(&net_b);

            let mut merged = ChangeLog::default();
            let mut op_log = ChangeLog::default();
            for _ in 0..ops {
                op_log.clear();
                let alive: Vec<u32> = net_a.ids().collect();
                match rng.below(4) {
                    0 | 1 => {
                        // Move (the common case).
                        let id = alive[rng.index(alive.len())];
                        let old = net_a.pos(id);
                        let new = Vec3::new(rng.f32(), rng.f32(), rng.f32());
                        net_a.set_pos(id, new);
                        net_b.set_pos(id, new);
                        op_log.moved.push((id, old));
                    }
                    2 => {
                        // Insert (reuses freed slab slots when available).
                        let p = Vec3::new(rng.f32(), rng.f32(), rng.f32());
                        let id_a = net_a.insert(p, 0.1);
                        let id_b = net_b.insert(p, 0.1);
                        if id_a != id_b {
                            return Err(format!("slab divergence {id_a} vs {id_b}"));
                        }
                        op_log.inserted.push(id_a);
                    }
                    _ => {
                        // Remove (keep at least 3 units alive).
                        if alive.len() > 3 {
                            let id = alive[rng.index(alive.len())];
                            let pos = net_a.pos(id);
                            net_a.remove(id);
                            net_b.remove(id);
                            op_log.removed.push((id, pos));
                        }
                    }
                }
                // Per-op cadence for A…
                idx_a.sync_with_net(&net_a, &op_log);
                // …accumulate for B's single merged sync.
                merged.moved.extend_from_slice(&op_log.moved);
                merged.inserted.extend_from_slice(&op_log.inserted);
                merged.removed.extend_from_slice(&op_log.removed);
            }
            idx_b.sync_with_net(&net_b, &merged);

            idx_a.grid().check_invariants().map_err(|e| format!("per-op grid: {e}"))?;
            idx_b.grid().check_invariants().map_err(|e| format!("merged grid: {e}"))?;
            if idx_a.grid().len() != idx_b.grid().len() {
                return Err(format!(
                    "indexed counts diverge: {} vs {}",
                    idx_a.grid().len(),
                    idx_b.grid().len()
                ));
            }
            let (pa, pb) = (probe(&idx_a, seed ^ 0xA5), probe(&idx_b, seed ^ 0xA5));
            if pa != pb {
                return Err("neighborhood membership diverges".into());
            }
            Ok(())
        },
    );
}

/// PR-2 — the lane-blocked SIMD kernel is bit-identical to the exhaustive
/// reference scan. The generator covers every tricky regime by
/// construction: live counts that are not a multiple of the lane width,
/// dead slots interleaved through the slab, exact distance ties (quantized
/// coordinates), and networks with fewer than two live units.
#[test]
fn prop_lane_kernel_bit_identical_to_exhaustive() {
    use msgsn::findwinners::{exhaustive_top2, lanes};
    Prop::new(150, 9).run(
        |rng, size| {
            let units = sized_usize(rng, size, 0, 211);
            let mut net = Network::new();
            let mut ids = Vec::new();
            for _ in 0..units {
                // Quantized coordinates force exact distance ties.
                let p = Vec3::new(
                    rng.index(4) as f32 * 0.25,
                    rng.index(4) as f32 * 0.25,
                    rng.index(4) as f32 * 0.25,
                );
                ids.push(net.insert(p, 0.1));
            }
            for &id in &ids {
                if rng.index(5) == 0 {
                    net.remove(id);
                }
            }
            let sigs: Vec<Vec3> = (0..20)
                .map(|_| {
                    Vec3::new(
                        rng.index(5) as f32 * 0.2,
                        rng.index(5) as f32 * 0.2,
                        rng.index(5) as f32 * 0.2,
                    )
                })
                .collect();
            (net, sigs)
        },
        |(net, sigs)| {
            net.check_invariants().map_err(|e| format!("generator: {e}"))?;
            for (k, s) in sigs.iter().enumerate() {
                let want = exhaustive_top2(net, *s);
                let got = lanes::lane_top2(net, *s);
                let same = match (want, got) {
                    (None, None) => true,
                    (Some(a), Some(b)) => {
                        a.w1 == b.w1
                            && a.w2 == b.w2
                            && a.d1_sq.to_bits() == b.d1_sq.to_bits()
                            && a.d2_sq.to_bits() == b.d2_sq.to_bits()
                    }
                    _ => false,
                };
                if !same {
                    return Err(format!(
                        "signal {k}: exhaustive {want:?} vs lane-blocked {got:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Satellite (PR 5) — the OBJ/OFF parsers are *total*: truncated, spliced,
/// token-mutated and NaN/inf-injected documents come back as `Err` (or as a
/// valid mesh when the mutation happened to be harmless), never as a panic
/// — and a non-finite coordinate is never accepted into a mesh.
#[test]
fn prop_mesh_parsers_total_on_malformed_input() {
    use msgsn::mesh::{parse_obj, parse_off};

    const OBJ: &str = "# corpus\nv 0 0 0\nv 1 0 0\nv 1 1 0\nv 0 1 0\nv 0 0 1\n\
                       f 1 2 3 4\nf 1/1/1 2/2 -1\nf 1 2 5\n";
    const OFF: &str = "OFF\n# corpus\n5 3 0\n0 0 0\n1 0 0\n1 1 0\n0 1 0\n0 0 1\n\
                       3 0 1 2\n4 0 1 2 3\n3 0 1 4\n";
    // ASCII-only: the truncation mutation cuts at raw byte offsets, which
    // is only a char boundary because the whole corpus stays ASCII.
    const GARBAGE: [&str; 14] = [
        "nan", "NaN", "inf", "-inf", "1e999", "-1", "0x10", "", "f", "v", "OFF",
        "999999999999999999999", "18446744073709551615", "1/2/3/4",
    ];

    // The unmutated corpus must parse — otherwise every mutation case is
    // vacuous.
    assert!(parse_obj(OBJ).is_ok());
    assert!(parse_off(OFF).is_ok());

    Prop::new(400, 0xF00D).run(
        |rng, _size| {
            let base = if rng.below(2) == 0 { OBJ } else { OFF };
            let mut text = base.to_string();
            for _ in 0..rng.below(3) + 1 {
                match rng.below(5) {
                    0 => {
                        // Truncate at a random byte (corpus is ASCII, so
                        // every cut is a char boundary).
                        text.truncate(rng.index(text.len() + 1));
                    }
                    1 => {
                        // Replace one whitespace-delimited token.
                        let tokens: Vec<&str> = text.split_whitespace().collect();
                        if !tokens.is_empty() {
                            let victim = tokens[rng.index(tokens.len())].to_string();
                            let sub = GARBAGE[rng.index(GARBAGE.len())];
                            text = text.replacen(&victim, sub, 1);
                        }
                    }
                    2 => {
                        // Insert a garbage line at a random line position.
                        let mut lines: Vec<String> =
                            text.lines().map(|l| l.to_string()).collect();
                        let line = format!(
                            "{} {} {}",
                            GARBAGE[rng.index(GARBAGE.len())],
                            GARBAGE[rng.index(GARBAGE.len())],
                            GARBAGE[rng.index(GARBAGE.len())],
                        );
                        lines.insert(rng.index(lines.len() + 1), line);
                        text = lines.join("\n");
                    }
                    3 => {
                        // Delete a random line (drops counts/vertices out
                        // from under OFF's header).
                        let mut lines: Vec<String> =
                            text.lines().map(|l| l.to_string()).collect();
                        if !lines.is_empty() {
                            lines.remove(rng.index(lines.len()));
                            text = lines.join("\n");
                        }
                    }
                    _ => {
                        // Duplicate a random line (duplicate headers,
                        // inflated counts).
                        let mut lines: Vec<String> =
                            text.lines().map(|l| l.to_string()).collect();
                        if !lines.is_empty() {
                            let l = lines[rng.index(lines.len())].clone();
                            lines.insert(rng.index(lines.len() + 1), l);
                            text = lines.join("\n");
                        }
                    }
                }
            }
            text
        },
        |text| {
            // Feed the mutant to BOTH parsers (an OBJ mutant is a malformed
            // OFF document and vice versa — twice the coverage per case).
            let outcome = std::panic::catch_unwind(|| {
                let results = [parse_obj(text), parse_off(text)];
                for r in results {
                    if let Ok(mesh) = r {
                        for v in &mesh.vertices {
                            if !v.is_finite() {
                                return Err(format!("accepted non-finite vertex {v:?}"));
                            }
                        }
                        for f in &mesh.faces {
                            if f.iter().any(|&i| i as usize >= mesh.vertices.len()) {
                                return Err(format!("accepted out-of-range face {f:?}"));
                            }
                        }
                    }
                }
                Ok(())
            });
            match outcome {
                Err(_) => Err("parser panicked".into()),
                Ok(verdict) => verdict,
            }
        },
    );
}

/// Satellite (PR 7) — checkpoint restore is *total* on corruption. Two
/// regimes:
///
/// 1. **Exhaustive single-bit sweep**: flipping any one bit anywhere in a
///    v2 snapshot is a clean `Err` — the CRC-32 trailer detects every
///    1-bit corruption by construction (flips inside the magic/version
///    fail those probes first). Never a panic, never a false restore.
/// 2. **Random splice/truncate/garbage corruption**, half of it
///    *re-checksummed* so the trailer validates and the decode is forced
///    past the CRC into the total `ByteReader` (bounds checks, the
///    oversized-allocation guard on length prefixes, network invariant
///    validation): never a panic; non-forged corruption never restores.
#[test]
fn prop_snapshot_restore_total_on_corruption() {
    use msgsn::config::{Algorithm, Driver, RunConfig};
    use msgsn::engine::ConvergenceSession;
    use msgsn::fleet::snapshot::{restore_session, snapshot_session};
    use msgsn::runtime::bytes::crc32;
    use std::cell::RefCell;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.driver = Driver::Multi;
    cfg.algorithm = Algorithm::Soam;
    cfg.seed = 41;
    cfg.mesh_resolution = 16;
    cfg.soam.insertion_threshold = 0.2;
    cfg.limits.max_signals = 4_000;
    let mesh = benchmark_mesh(cfg.shape, cfg.mesh_resolution);
    let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
    session.step(3);
    let bytes = snapshot_session(&session);

    // Regime 1 — every bit of every byte. Failed restores never get past
    // the magic/version/CRC probes, so the target session stays clean and
    // can be reused across the whole sweep.
    let mut target = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
    for byte in 0..bytes.len() {
        for bit in 0..8 {
            let mut flipped = bytes.clone();
            flipped[byte] ^= 1 << bit;
            match catch_unwind(AssertUnwindSafe(|| restore_session(&mut target, &flipped))) {
                Err(_) => panic!("flip at byte {byte} bit {bit} panicked"),
                Ok(Ok(())) => panic!("flip at byte {byte} bit {bit} restored as valid"),
                Ok(Err(_)) => {}
            }
        }
    }

    // Regime 2 — random structural corruption through the mini harness.
    // The target may come back partially overwritten after a forged-CRC
    // case (restore_session's documented contract), which is exactly the
    // dirty-session state the fleet guards against by rebuilding — here it
    // only ever receives further restore attempts, which must stay total.
    let dirty = RefCell::new(ConvergenceSession::new(&cfg, &mesh, None).unwrap());
    Prop::new(250, 0xC0FFEE).run(
        |rng, _size| {
            let mut m = bytes.clone();
            for _ in 0..rng.below(4) + 1 {
                match rng.below(4) {
                    0 => m.truncate(rng.index(m.len() + 1)),
                    1 => {
                        if !m.is_empty() {
                            let i = rng.index(m.len());
                            m[i] = rng.below(256) as u8;
                        }
                    }
                    2 => {
                        // Splice garbage bytes at a random offset.
                        let at = rng.index(m.len() + 1);
                        for k in 0..rng.below(9) as usize {
                            m.insert(at + k, 0xAB);
                        }
                    }
                    _ => {
                        // Stamp a huge little-endian u32 somewhere — when it
                        // lands on a length prefix, the reader's allocation
                        // guard (not an OOM abort) must reject it.
                        if m.len() >= 4 {
                            let at = rng.index(m.len() - 3);
                            m[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
                        }
                    }
                }
            }
            let forged = rng.below(2) == 0 && m.len() > 12;
            if forged {
                let len = m.len();
                let crc = crc32(&m[..len - 4]);
                m[len - 4..].copy_from_slice(&crc.to_le_bytes());
            }
            (m, forged)
        },
        |(m, forged)| {
            let verdict = catch_unwind(AssertUnwindSafe(|| {
                restore_session(&mut dirty.borrow_mut(), m)
            }));
            match verdict {
                Err(_) => Err("restore panicked on corrupt input".into()),
                Ok(Ok(())) if !forged && m != &bytes => {
                    Err("non-forged corruption restored as valid".into())
                }
                Ok(_) => Ok(()),
            }
        },
    );
}

/// Satellite (PR 8) — the dist wire codec is *total*. Three regimes over
/// every message type (via [`msgsn::dist::wire::sample_messages`], so new
/// variants are covered by construction):
///
/// 1. **Exhaustive truncation sweep**: every proper prefix of every
///    frame decodes to a clean `Err` — never a panic, never a partial
///    message.
/// 2. **Exhaustive single-bit flips**: the per-frame CRC-32 detects every
///    1-bit corruption by construction (header flips fail the
///    magic/length probes first).
/// 3. **Randomized structural mutation**, half of it *re-forged*
///    (magic/length/CRC made consistent again) so decode is driven past
///    the frame checks into the payload reader — whose bounds checks and
///    length-prefix allocation guards must stay total on garbage.
#[test]
fn prop_wire_codec_total_on_corruption() {
    use msgsn::dist::wire::{
        decode_frame, encode_frame, sample_messages, FRAME_MAGIC, FRAME_OVERHEAD,
    };
    use msgsn::runtime::bytes::crc32;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let frames: Vec<Vec<u8>> = sample_messages().iter().map(encode_frame).collect();

    // Regime 1 — every prefix of every frame.
    for (k, frame) in frames.iter().enumerate() {
        assert!(decode_frame(frame).is_ok(), "sample {k} must round-trip");
        for cut in 0..frame.len() {
            match catch_unwind(AssertUnwindSafe(|| decode_frame(&frame[..cut]))) {
                Err(_) => panic!("sample {k} truncated to {cut} bytes panicked"),
                Ok(Ok(_)) => panic!("sample {k} truncated to {cut} bytes decoded as valid"),
                Ok(Err(_)) => {}
            }
        }
    }

    // Regime 2 — every bit of every byte of every frame.
    for (k, frame) in frames.iter().enumerate() {
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut m = frame.clone();
                m[byte] ^= 1 << bit;
                match catch_unwind(AssertUnwindSafe(|| decode_frame(&m))) {
                    Err(_) => panic!("sample {k} flip at byte {byte} bit {bit} panicked"),
                    Ok(Ok(_)) => {
                        panic!("sample {k} flip at byte {byte} bit {bit} decoded as valid")
                    }
                    Ok(Err(_)) => {}
                }
            }
        }
    }

    // Regime 3 — random splice/truncate/garbage/huge-length mutation.
    Prop::new(250, 0xD157).run(
        |rng, _size| {
            let mut m = frames[rng.index(frames.len())].clone();
            for _ in 0..rng.below(4) + 1 {
                match rng.below(4) {
                    0 => m.truncate(rng.index(m.len() + 1)),
                    1 => {
                        if !m.is_empty() {
                            let i = rng.index(m.len());
                            m[i] = rng.below(256) as u8;
                        }
                    }
                    2 => {
                        // Splice garbage bytes at a random offset.
                        let at = rng.index(m.len() + 1);
                        for k in 0..rng.below(9) as usize {
                            m.insert(at + k, 0xCD);
                        }
                    }
                    _ => {
                        // Stamp a huge little-endian u32 — on the frame
                        // length it must hit the size cap, on a payload
                        // string/bytes length prefix the reader's bounds
                        // check (not an OOM abort) must reject it.
                        if m.len() >= 4 {
                            let at = rng.index(m.len() - 3);
                            m[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
                        }
                    }
                }
            }
            let forged = rng.below(2) == 0 && m.len() >= FRAME_OVERHEAD;
            if forged {
                let len = m.len() - FRAME_OVERHEAD;
                m[..4].copy_from_slice(&FRAME_MAGIC);
                m[4..8].copy_from_slice(&(len as u32).to_le_bytes());
                let crc = crc32(&m[8..8 + len]);
                let total = m.len();
                m[total - 4..].copy_from_slice(&crc.to_le_bytes());
            }
            (m, forged)
        },
        |(m, forged)| {
            match catch_unwind(AssertUnwindSafe(|| decode_frame(m))) {
                Err(_) => Err("decode panicked on corrupt frame".into()),
                // A forged frame may legitimately decode (the mutation can
                // be harmless once re-checksummed); an unforged one only
                // if it IS one of the originals.
                Ok(Ok(_)) if !forged && !frames.iter().any(|f| f == m) => {
                    Err("unforged corruption decoded as a valid frame".into())
                }
                Ok(_) => Ok(()),
            }
        },
    );
}

/// PR-2 — sharding `find2_batch` across the persistent worker pool must not
/// change a single bit of any `Winners` for any `find_threads`.
#[test]
fn pool_sharded_batch_identical_for_find_threads_1_2_7() {
    use msgsn::runtime::WorkerPool;
    use std::sync::Arc;
    let mut rng = Rng::seed_from(77);
    let net = random_net(&mut rng, 700);
    // Enough signals that the per-shard minimum engages for every count.
    let sigs: Vec<Vec3> = (0..1000)
        .map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32()))
        .collect();
    let mut base = Vec::new();
    BatchRust::default().find2_batch(&net, &sigs, &mut base);
    assert!(base.iter().all(|w| w.is_some()));
    for find_threads in [1usize, 2, 7] {
        let mut fw = BatchRust::default();
        fw.attach_pool(Arc::new(WorkerPool::new(find_threads)), find_threads);
        let mut got = Vec::new();
        fw.find2_batch(&net, &sigs, &mut got);
        assert_eq!(got, base, "find_threads {find_threads}");
    }
}
