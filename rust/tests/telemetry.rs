//! Telemetry integration tests — the PR 10 acceptance points.
//!
//! The non-negotiable invariant: **telemetry is a pure observer**. A run
//! with the full instrument registry and event trace enabled must be
//! bit-identical — same networks, same counters, same encoded session
//! bytes — to the same run with telemetry off. Everything else here is
//! exposition plumbing:
//!
//! - **on ≡ off parity**: SOAM, GWR and GNG across the Multi / Parallel /
//!   Pipelined drivers and regions ∈ {1, 27}, proven by
//!   `assert_networks_identical` plus byte-equal `snapshot_session`;
//! - **instrument catalog**: a checkpointing fleet run populates the
//!   per-phase time totals, signal/batch/pool counters, the checkpoint
//!   write-latency histogram, and the job-lifecycle trace, all visible
//!   through `metrics_json` and the Prometheus text rendering;
//! - **trace narrative**: a crash-and-retry fleet run and a
//!   kill-and-migrate dist run both replay as ordered, parseable JSONL;
//! - **serve `metrics` verb**: polling a converging daemon returns
//!   monotone counters and leaves the final encoded session byte-equal
//!   to an unobserved run;
//! - **ring overflow**: the event ring drops oldest and counts drops.
//!
//! Every test serializes on `telemetry::test_lock()` (the registry and
//! ring are process-global); tests that also arm fault specs take
//! `fault::test_lock()` after it, in that order, like the fleet suite.

use std::path::PathBuf;
use std::time::Duration;

use msgsn::config::{Algorithm, Driver, RunConfig};
use msgsn::engine::ConvergenceSession;
use msgsn::fleet::snapshot::snapshot_session;
use msgsn::fleet::{parse_manifest, Fleet, FleetOptions, FleetOutcome, JobSpec};
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, Mesh};
use msgsn::runtime::fault;
use msgsn::runtime::{parse_json, Json};
use msgsn::som::Network;
use msgsn::telemetry::{self, Counter};

/// Bitwise network equality (same contract as the executor-parity suite).
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let mut ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let mut eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

/// Run a session to convergence; return it with the full encoded-session
/// bytes and the report counters that must match across on/off.
fn run_to_completion(cfg: &RunConfig, mesh: &Mesh) -> (ConvergenceSession, Vec<u8>, [u64; 3], u32) {
    let mut session = ConvergenceSession::new(cfg, mesh, None).unwrap();
    while session.step(17) {}
    let bytes = snapshot_session(&session);
    let r = session.finish();
    (session, bytes, [r.iterations, r.signals, r.discarded], r.qe.to_bits())
}

fn parity_config(algorithm: Algorithm, driver: Driver, regions: usize) -> (RunConfig, Mesh) {
    // GNG gets the Eight mesh (its insertion schedule is the interesting
    // path there); SOAM/GWR the Blob — mirroring the executor-parity suite.
    let shape = match algorithm {
        Algorithm::Gng => BenchmarkShape::Eight,
        _ => BenchmarkShape::Blob,
    };
    let mut cfg = RunConfig::preset(shape);
    cfg.algorithm = algorithm;
    cfg.driver = driver;
    cfg.regions = regions;
    cfg.seed = 47;
    cfg.mesh_resolution = 16;
    cfg.soam.insertion_threshold = 0.2;
    cfg.gwr.insertion_threshold = 0.12;
    cfg.gng.lambda = 60;
    cfg.limits.max_signals = 8_000;
    if driver != Driver::Multi {
        cfg.update_threads = 2;
        cfg.find_threads = 2;
    }
    (cfg, benchmark_mesh(shape, 16))
}

/// The tentpole invariant: full telemetry (registry + trace) changes
/// **nothing** — not one bit of the network, not one byte of the encoded
/// session — for any algorithm × driver × regions combination.
#[test]
fn telemetry_on_runs_are_bit_identical_to_off() {
    let _guard = telemetry::test_lock();
    let mut combos: Vec<(Algorithm, Driver, usize)> = Vec::new();
    for algorithm in [Algorithm::Soam, Algorithm::Gng] {
        for driver in [Driver::Multi, Driver::Parallel, Driver::Pipelined] {
            for regions in [1usize, 27] {
                combos.push((algorithm, driver, regions));
            }
        }
    }
    // GWR rides one parallel region combo (its global insertion threshold
    // is the third deferred-insert flavor).
    combos.push((Algorithm::Gwr, Driver::Parallel, 27));

    for (algorithm, driver, regions) in combos {
        let (cfg, mesh) = parity_config(algorithm, driver, regions);
        let label = format!("{:?}/{:?}/regions={regions}", algorithm, driver);

        telemetry::set_enabled(false);
        let (off_session, off_bytes, off_counts, off_qe) = run_to_completion(&cfg, &mesh);

        telemetry::set_enabled(true);
        let (on_session, on_bytes, on_counts, on_qe) = run_to_completion(&cfg, &mesh);

        assert_eq!(off_counts, on_counts, "{label}: report counters");
        assert_eq!(off_qe, on_qe, "{label}: qe bits");
        assert_networks_identical(
            off_session.algo().net(),
            on_session.algo().net(),
            &label,
        );
        assert_eq!(
            off_bytes, on_bytes,
            "{label}: telemetry-on encoded session differs from telemetry-off"
        );
        // The observer actually observed: the enabled run moved counters.
        assert!(
            telemetry::counter(Counter::SignalsProcessed) > 0,
            "{label}: enabled run recorded nothing"
        );
    }
}

fn tiny_spec(name: &str, seed: u64) -> JobSpec {
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.driver = Driver::Multi;
    cfg.algorithm = Algorithm::Soam;
    cfg.seed = seed;
    cfg.mesh_resolution = 16;
    cfg.soam.insertion_threshold = 0.2;
    cfg.limits.max_signals = 4_000;
    JobSpec::from_config(name, cfg)
}

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("msgsn_tel_{}_{}", std::process::id(), name));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A checkpointing fleet run populates the whole instrument catalog the
/// acceptance list names: phase time totals, signal/batch counts, pool
/// traffic, the checkpoint write-latency histogram, and the lifecycle
/// trace — all visible through `metrics_json` and the Prometheus text.
#[test]
fn fleet_run_populates_the_instrument_catalog() {
    let _guard = telemetry::test_lock();
    let _faults = fault::test_lock();
    fault::clear();
    telemetry::set_enabled(true);
    let dir = scratch_dir("catalog");
    let opts = FleetOptions {
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.clone()),
        ..FleetOptions::default()
    };
    // Parallel driver with real thread counts so the pool instruments
    // (job/steal counters) genuinely move.
    let mut spec = tiny_spec("tel-cat", 5);
    spec.cfg.driver = Driver::Parallel;
    spec.cfg.update_threads = 2;
    spec.cfg.find_threads = 2;
    let mut fleet = Fleet::new(vec![spec]).unwrap();
    let report = fleet.run(&opts, |_| {}).unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);

    let doc = telemetry::metrics_json(64);
    let counter = |name: &str| -> u64 {
        doc.get("counters")
            .and_then(|c| c.get(name))
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("counter {name} missing: {doc:?}"))
    };
    for name in [
        "msgsn_signals_processed_total",
        "msgsn_batches_total",
        "msgsn_pool_jobs_total",
        "msgsn_phase_sample_nanos_total",
        "msgsn_phase_find_nanos_total",
        "msgsn_phase_update_nanos_total",
        "msgsn_jobs_admitted_total",
        "msgsn_checkpoints_written_total",
    ] {
        assert!(counter(name) > 0, "{name} never moved: {doc:?}");
    }
    let hist = doc
        .get("histograms")
        .and_then(|h| h.get("msgsn_checkpoint_write_nanos"))
        .expect("checkpoint write histogram missing");
    assert!(
        hist.get("count").and_then(|v| v.as_u64()).unwrap_or(0) > 0,
        "histogram recorded no write-outs: {hist:?}"
    );
    let kinds: Vec<&str> = doc
        .get("trace")
        .and_then(Json::as_arr)
        .expect("trace array")
        .iter()
        .filter_map(|e| e.get("kind").and_then(Json::as_str))
        .collect();
    assert!(kinds.contains(&"job_admitted"), "{kinds:?}");
    assert!(kinds.contains(&"job_done"), "{kinds:?}");

    // Prometheus rendering carries every instrument family.
    let text = telemetry::snapshot().render_prometheus();
    assert!(text.contains("# TYPE msgsn_signals_processed_total counter"), "{text}");
    assert!(text.contains("# TYPE msgsn_checkpoint_write_nanos histogram"), "{text}");
    assert!(text.contains("msgsn_checkpoint_write_nanos_bucket{le=\"+Inf\"}"), "{text}");

    // Satellite: per-job phase times aggregate into the fleet report.
    let totals = report.phase_totals();
    assert!(totals.sample + totals.find + totals.update > Duration::ZERO);
    let row_json = report.rows[0].to_json();
    let rep = row_json.get("report").expect("report object");
    for key in ["sample_s", "find_s", "update_s"] {
        assert!(rep.get(key).and_then(|v| v.as_f64()).is_some(), "{key} missing: {rep:?}");
    }
    let report_json = report.to_json();
    assert!(report_json.get("phase_totals").is_some(), "{report_json:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A crash-and-retry fleet run replays as an ordered narrative: admitted,
/// then failed, then retried, then done — with monotone sequence numbers
/// and parseable JSONL throughout.
#[test]
fn trace_replays_crash_and_retry_in_order() {
    let _guard = telemetry::test_lock();
    let _faults = fault::test_lock();
    telemetry::set_enabled(true);
    msgsn::telemetry::trace::reset();
    fault::install(fault::parse_faults("job/tel-flaky:panic@turn=8").unwrap());
    let dir = scratch_dir("retry");
    let opts = FleetOptions {
        stride: 2,
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.clone()),
        ..FleetOptions::default()
    };
    let mut fleet = Fleet::new(vec![tiny_spec("tel-flaky", 29)]).unwrap();
    let report = fleet.run(&opts, |_| {}).unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
    assert!(telemetry::counter(Counter::JobsRetried) >= 1);

    let events = msgsn::telemetry::trace::drain_all();
    let jsonl = msgsn::telemetry::trace::to_jsonl(&events);
    let mut seqs = Vec::new();
    let mut kinds = Vec::new();
    for line in jsonl.lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad JSONL {line:?}: {e}"));
        seqs.push(doc.get("seq").and_then(|v| v.as_u64()).expect("seq"));
        kinds.push(doc.get("kind").and_then(Json::as_str).expect("kind").to_string());
    }
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seq not monotone: {seqs:?}");
    let pos = |kind: &str| {
        kinds
            .iter()
            .position(|k| k == kind)
            .unwrap_or_else(|| panic!("no {kind} event in {kinds:?}"))
    };
    assert!(pos("job_admitted") < pos("job_failed"), "{kinds:?}");
    assert!(pos("job_failed") < pos("job_retried"), "{kinds:?}");
    assert!(pos("job_retried") < pos("job_done"), "{kinds:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A kill-and-migrate dist run replays as ordered JSONL carrying the
/// whole story — checkpoint promotions, the eviction, the migration —
/// and moves the matching counters.
#[test]
fn dist_kill_and_migrate_replays_as_ordered_jsonl() {
    use msgsn::dist::{
        channel_transport_pair, run_worker, Coordinator, DistOptions, DistOutcome, WorkerOptions,
    };
    use msgsn::fleet::manifest_job_payloads;

    let _guard = telemetry::test_lock();
    let _faults = fault::test_lock();
    telemetry::set_enabled(true);
    msgsn::telemetry::trace::reset();
    fault::install(fault::parse_faults("worker/zz-tel-kill-w1:panic@turn=6").unwrap());

    let text = format!(
        r#"{{"version": 1, "jobs": [{}, {}]}}"#,
        r#"{"name": "tk-a", "mesh": "blob", "algorithm": "soam", "driver": "multi",
            "seed": 21,
            "config": {"mesh_resolution": 16, "insertion_threshold": 0.2,
                       "max_signals": 4000}}"#,
        r#"{"name": "tk-b", "mesh": "blob", "algorithm": "soam", "driver": "multi",
            "seed": 22,
            "config": {"mesh_resolution": 16, "insertion_threshold": 0.2,
                       "max_signals": 4000}}"#,
    );
    let mut coordinator = Coordinator::new(
        manifest_job_payloads(&text).unwrap(),
        DistOptions { heartbeat_timeout: Duration::from_secs(30), ..DistOptions::default() },
    );
    let workers: Vec<_> = ["zz-tel-kill-w0", "zz-tel-kill-w1"]
        .iter()
        .map(|name| {
            let (coord_end, mut worker_end) = channel_transport_pair(name);
            coordinator.add_worker(name, Box::new(coord_end));
            let opts = WorkerOptions {
                name: name.to_string(),
                stride: 1,
                checkpoint_rounds: 2,
                idle_poll: Duration::from_millis(2),
            };
            std::thread::Builder::new()
                .name(format!("msgsn-{name}"))
                .spawn(move || run_worker(&mut worker_end, &opts, |_| {}))
                .unwrap()
        })
        .collect();
    let report = coordinator.run(|_| {});
    for w in workers {
        let _ = w.join(); // w1's thread died on the injected panic
    }
    assert_eq!(report.outcome(), DistOutcome::AllDone, "{report:?}");
    assert!(report.rows.iter().any(|r| r.migrations >= 1), "{report:?}");

    assert!(telemetry::counter(Counter::WorkersEvicted) >= 1);
    assert!(telemetry::counter(Counter::JobsMigrated) >= 1);
    assert!(telemetry::counter(Counter::FramesSent) > 0);
    assert!(telemetry::counter(Counter::FramesReceived) > 0);

    let events = msgsn::telemetry::trace::drain_all();
    let jsonl = msgsn::telemetry::trace::to_jsonl(&events);
    let mut last_seq = None;
    let mut kinds = Vec::new();
    for line in jsonl.lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad JSONL {line:?}: {e}"));
        let seq = doc.get("seq").and_then(|v| v.as_u64()).expect("seq");
        assert!(last_seq.is_none_or(|p| p < seq), "seq regressed at {line}");
        last_seq = Some(seq);
        kinds.push(doc.get("kind").and_then(Json::as_str).expect("kind").to_string());
    }
    for kind in ["job_admitted", "checkpoint_promoted", "worker_evicted", "job_migrated"] {
        assert!(kinds.iter().any(|k| k == kind), "no {kind} in {kinds:?}");
    }
}

/// Serve `metrics` polls against a converging daemon: counters are
/// monotone across polls, the Prometheus text renders, and the final
/// encoded session is byte-equal to an unobserved batch run — the verb
/// reads the registry, never the fleet.
#[test]
fn serve_metrics_polls_do_not_perturb_convergence() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    use msgsn::serve::{ServeOptions, Server};

    let _guard = telemetry::test_lock();
    let _faults = fault::test_lock();
    fault::clear();
    telemetry::set_enabled(true);
    let name = "tel-serve";
    let job = r#"{"name": "tel-serve", "mesh": "blob", "algorithm": "soam", "driver": "multi",
                  "seed": 77,
                  "config": {"mesh_resolution": 16, "insertion_threshold": 0.2,
                             "max_signals": 4000}}"#;

    // Unobserved reference: the same spec through the batch fleet.
    let manifest = format!(r#"{{"version": 1, "jobs": [{job}]}}"#);
    let specs = parse_manifest(&manifest).unwrap();
    let mut reference = Fleet::new(specs).unwrap();
    reference.run(&FleetOptions::default(), |_| {}).unwrap();

    let mut server = Server::bind("127.0.0.1:0", Vec::new()).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::Builder::new()
        .name("msgsn-tel-serve".to_string())
        .spawn(move || {
            let opts = ServeOptions {
                idle_poll: Duration::from_millis(1),
                watch_every: 4,
                ..ServeOptions::default()
            };
            let report = server.run(&opts, |_| {}).unwrap();
            (server, report)
        })
        .unwrap();

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut reader = BufReader::new(stream);
    let mut request = |line: &str| -> Json {
        let s = reader.get_mut();
        s.write_all(line.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        loop {
            let mut resp = String::new();
            assert!(reader.read_line(&mut resp).unwrap() > 0, "daemon hung up");
            let doc = parse_json(resp.trim()).unwrap();
            if doc.get("ok").is_some() {
                assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(true), "{doc:?}");
                return doc;
            }
            // Stray event line (none expected — we never watch).
        }
    };

    let resp = request(&format!(r#"{{"cmd": "submit", "job": {job}}}"#));
    assert_eq!(resp.get("job").and_then(Json::as_str), Some(name));

    // Poll metrics while the job converges; the signal counter must be
    // monotone poll over poll.
    let signals_of = |doc: &Json| -> u64 {
        doc.get("metrics")
            .and_then(|m| m.get("counters"))
            .and_then(|c| c.get("msgsn_signals_processed_total"))
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("no signal counter: {doc:?}"))
    };
    let mut polls = Vec::new();
    loop {
        let m = request(r#"{"cmd": "metrics"}"#);
        assert!(
            m.get("text")
                .and_then(Json::as_str)
                .is_some_and(|t| t.contains("# TYPE msgsn_signals_processed_total counter")),
            "prometheus text missing: {m:?}"
        );
        polls.push(signals_of(&m));
        let status = request(r#"{"cmd": "status"}"#);
        let rows = status.get("jobs").and_then(Json::as_arr).unwrap();
        if rows[0].get("status").and_then(Json::as_str) == Some("done") {
            break;
        }
    }
    assert!(polls.len() >= 2, "the metrics polls never ran");
    assert!(polls.windows(2).all(|w| w[0] <= w[1]), "counters regressed: {polls:?}");
    assert!(*polls.last().unwrap() > 0, "no signals were ever counted");

    request(r#"{"cmd": "shutdown"}"#);
    let (server, report) = handle.join().unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
    let observed = server
        .fleet()
        .jobs()
        .iter()
        .find(|j| j.spec().name == name)
        .unwrap()
        .session()
        .unwrap();
    let unobserved = reference.jobs()[0].session().unwrap();
    assert_networks_identical(observed.algo().net(), unobserved.algo().net(), name);
    assert_eq!(
        snapshot_session(observed),
        snapshot_session(unobserved),
        "metrics polls perturbed the encoded session"
    );
}

/// The event ring under overflow: oldest events evicted, drops counted,
/// sequence numbers preserved across the gap — via the public crate API.
#[test]
fn event_ring_overflow_drops_oldest_and_counts() {
    let _guard = telemetry::test_lock();
    telemetry::set_enabled(true);
    msgsn::telemetry::trace::set_capacity(8);
    for k in 0..20u64 {
        telemetry::emit("job_admitted", Some(&format!("ring-{k}")), vec![]);
    }
    let events = msgsn::telemetry::trace::tail(100);
    assert_eq!(events.len(), 8);
    assert_eq!(events[0].job.as_deref(), Some("ring-12"));
    assert_eq!(events[7].job.as_deref(), Some("ring-19"));
    assert_eq!(msgsn::telemetry::trace::dropped(), 12);
    assert_eq!(telemetry::counter(Counter::TraceEventsDropped), 12);
    assert_eq!(events[7].seq, 19, "seq keeps counting across drops");
    let doc = telemetry::metrics_json(4);
    assert_eq!(doc.get("trace").and_then(Json::as_arr).map(|a| a.len()), Some(4));
    assert_eq!(doc.get("trace_dropped").and_then(|v| v.as_u64()), Some(12));
}
