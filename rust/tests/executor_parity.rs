//! Refactor-parity tests for the unified batch-update executor.
//!
//! `reference_multi_signal` below is a line-for-line copy of the
//! pre-refactor `engine::run_multi_signal` loop (per-signal winner locks,
//! linear-scan staleness guard, one `fw.sync` per applied signal) — kept
//! here as the executable specification. The refactored drivers must
//! reproduce it bit-for-bit:
//!
//! - `Driver::Multi` through the shared `BatchExecutor` (merged per-batch
//!   sync, AABB-early-exit staleness guard) must match the reference on
//!   every unit position, firing level, edge and report counter;
//! - `Driver::Parallel` must match `Driver::Multi` for any
//!   `update_threads`, including auto-detect — for SOAM, GWR **and GNG**
//!   (possible since PR 3's lazy error decay; the GNG case covers the
//!   pending-aware insertion-schedule classification, the concurrent
//!   commit, and deterministic slab-id assignment on the sharded free
//!   lists);
//! - `Driver::Pipelined` must be invariant in `update_threads` for any
//!   `queue_depth` (the prefetch composed with the pooled Update split);
//! - with `regions > 1` (PR 4) the region-sharded schedule — the
//!   region-neighborhood Find Winners scan plus the executor's
//!   region-granular conflict domains and deferred insert commits — must
//!   be bit-identical to `Multi` for any `(regions, update_threads,
//!   find_threads, queue_depth)` combination.
//!
//! The CI correctness matrix injects extra combinations through
//! `MSGSN_TEST_UPDATE_THREADS` / `MSGSN_TEST_FIND_THREADS` /
//! `MSGSN_TEST_REGIONS` / `MSGSN_TEST_QUEUE_DEPTH` (see
//! `.github/workflows/ci.yml`); unset, the in-repo combinations run alone.
//! PR 6 adds two SIMD cells to the same matrix: `MSGSN_FW_ISA=fallback`
//! (every run on the portable tier) and `-C target-cpu=native` (the widest
//! tier the runner supports, compiled for the exact host ISA) — plus the
//! in-repo `fw_isa` parity test below.
//!
//! PR 5 adds the **snapshot/resume** acceptance tests: a
//! [`msgsn::engine::ConvergenceSession`] killed at random batch boundaries
//! (serialize → drop → rebuild from the spec → restore) must finish
//! bit-identical to the uninterrupted `Multi` reference, for SOAM, GWR and
//! GNG across the same knob matrix — and the pipelined session mode must
//! match the threaded `run_pipelined` driver under kill/resume too.

use msgsn::config::Limits;
use msgsn::coordinator::LockTable;
use msgsn::engine::{m_schedule, run_multi_signal, run_parallel};
use msgsn::findwinners::{BatchRust, FindWinners};
use msgsn::geometry::Vec3;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::som::{
    ChangeLog, GrowingNetwork, Gwr, GwrParams, Network, Soam, SoamParams, Winners,
};

/// The pre-refactor multi-signal driver loop, verbatim (modulo the report
/// struct: we only track the counters the assertions need).
#[allow(clippy::too_many_lines)]
fn reference_multi_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> (u64, u64, u64) {
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    let mut signals: Vec<Vec3> = Vec::new();
    let mut winners: Vec<Option<Winners>> = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    let mut locks = LockTable::new();
    let mut batch_inserted: Vec<Vec3> = Vec::new();

    let (mut iterations, mut total_signals, mut discarded) = (0u64, 0u64, 0u64);
    loop {
        iterations += 1;
        let m = m_schedule(algo.net().len(), limits.max_parallelism);

        sampler.sample_batch(rng, m, &mut signals);
        fw.find2_batch(algo.net(), &signals, &mut winners);

        rng.permutation(m, &mut order);
        locks.next_batch();
        locks.ensure_capacity(algo.net().capacity());
        batch_inserted.clear();
        for &j in &order {
            let w = match winners[j as usize] {
                Some(w) => w,
                None => {
                    discarded += 1;
                    continue;
                }
            };
            let signal = signals[j as usize];
            if !algo.net().is_alive(w.w1)
                || !algo.net().is_alive(w.w2)
                || batch_inserted.iter().any(|p| signal.dist2(*p) < w.d1_sq)
                || !locks.try_lock(w.w1)
            {
                discarded += 1;
                continue;
            }
            log.clear();
            algo.update(signal, &w, &mut log);
            for &id in &log.inserted {
                batch_inserted.push(algo.net().pos(id));
            }
            fw.sync(algo.net(), &log);
        }
        total_signals += m as u64;

        log.clear();
        let converged = algo.housekeeping(&mut log);
        if !log.is_empty() {
            fw.sync(algo.net(), &log);
        }
        if converged {
            break;
        }
        if total_signals >= limits.max_signals {
            break;
        }
    }
    (iterations, total_signals, discarded)
}

/// Bitwise network equality: slab layout, aliveness, positions, firing,
/// error, thresholds and the full aged edge sets.
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let mut ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let mut eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

fn limits(max_signals: u64) -> Limits {
    Limits { max_signals, ..Limits::default() }
}

fn blob_sampler() -> SurfaceSampler {
    SurfaceSampler::new(&benchmark_mesh(BenchmarkShape::Blob, 20))
}

/// One knob of the CI correctness matrix (unset / unparsable = None).
fn env_knob(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.parse().ok()
}

/// Extra `(update_threads, find_threads, regions)` combination injected by
/// the CI matrix; unset knobs default to the sequential value.
fn env_combo() -> Option<(usize, usize, usize)> {
    let upd = env_knob("MSGSN_TEST_UPDATE_THREADS");
    let find = env_knob("MSGSN_TEST_FIND_THREADS");
    let regions = env_knob("MSGSN_TEST_REGIONS");
    if upd.is_none() && find.is_none() && regions.is_none() {
        return None;
    }
    Some((upd.unwrap_or(1), find.unwrap_or(1), regions.unwrap_or(1)))
}

#[test]
fn multi_through_executor_matches_pre_refactor_reference() {
    for seed in [1u64, 9, 42] {
        let sampler = blob_sampler();
        let lim = limits(30_000);

        let mut soam_a = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_a = BatchRust::default();
        let mut rng_a = Rng::seed_from(seed);
        let (it_a, sig_a, disc_a) =
            reference_multi_signal(&mut soam_a, &sampler, &mut fw_a, &lim, &mut rng_a);

        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(seed);
        let r = run_multi_signal(&mut soam_b, &sampler, &mut fw_b, &lim, &mut rng_b);

        assert_eq!(it_a, r.iterations, "seed {seed}: iterations");
        assert_eq!(sig_a, r.signals, "seed {seed}: signals");
        assert_eq!(disc_a, r.discarded, "seed {seed}: discarded");
        assert_networks_identical(
            soam_a.net(),
            soam_b.net(),
            &format!("seed {seed}: multi vs reference"),
        );
    }
}

#[test]
fn parallel_matches_multi_for_every_thread_count() {
    for (seed, threads) in [(7u64, 1usize), (7, 2), (7, 4), (7, 0), (21, 3)] {
        let sampler = blob_sampler();
        let lim = limits(30_000);

        let mut soam_a = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_a = BatchRust::default();
        let mut rng_a = Rng::seed_from(seed);
        let a = run_multi_signal(&mut soam_a, &sampler, &mut fw_a, &lim, &mut rng_a);

        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(seed);
        let b = run_parallel(&mut soam_b, &sampler, &mut fw_b, &lim, &mut rng_b, threads);

        assert_eq!(a.iterations, b.iterations, "seed {seed} threads {threads}");
        assert_eq!(a.signals, b.signals, "seed {seed} threads {threads}");
        assert_eq!(a.discarded, b.discarded, "seed {seed} threads {threads}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "seed {seed} threads {threads}: qe");
        assert_networks_identical(
            soam_a.net(),
            soam_b.net(),
            &format!("seed {seed} threads {threads}: parallel vs multi"),
        );
    }
}

#[test]
fn pooled_plan_and_sharded_find_match_multi_bitwise() {
    // The full engine path: one shared worker pool per run (created in
    // run_convergence), plan pass pooled, Find Winners sharded — the final
    // network must still match the sequential multi driver bit-for-bit for
    // every (update_threads, find_threads) combination.
    use msgsn::config::{Driver, RunConfig};
    use msgsn::engine::run_convergence;

    let sampler = blob_sampler();
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.soam.insertion_threshold = 0.16;
    cfg.limits.max_signals = 30_000;

    let mut soam_a = Soam::new(SoamParams {
        insertion_threshold: 0.16,
        ..SoamParams::default()
    });
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(15);
    let a = run_multi_signal(&mut soam_a, &sampler, &mut fw_a, &cfg.limits, &mut rng_a);

    let mut combos = vec![
        (1usize, 2usize, 1usize),
        (3, 7, 1),
        (2, 2, 1),
        (0, 0, 1),
        // PR 4 acceptance: the region-sharded schedule is bit-identical
        // for any (regions, update_threads, find_threads).
        (1, 1, 8),
        (3, 2, 27),
        (0, 0, 64),
    ];
    combos.extend(env_combo());
    for (update_threads, find_threads, regions) in combos {
        cfg.driver = Driver::Parallel;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.regions = regions;
        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(15);
        let b = run_convergence(&mut soam_b, &sampler, &mut fw_b, &cfg, &mut rng_b);
        let label = format!("upd={update_threads} find={find_threads} regions={regions}");
        assert_eq!(a.iterations, b.iterations, "{label}");
        assert_eq!(a.signals, b.signals, "{label}");
        assert_eq!(a.discarded, b.discarded, "{label}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        assert_networks_identical(soam_a.net(), soam_b.net(), &label);
    }
}

/// Acceptance (PR 3): GNG under the `Parallel` driver is bit-identical to
/// the sequential `Multi` driver for any `(update_threads, find_threads)`
/// — including unit ids (deterministic shard-local allocation) and the
/// lazily decayed per-unit errors (when a unit materializes is itself part
/// of the deterministic operation sequence, so the stored error bits and
/// epoch stamps match across drivers without any final sweep).
#[test]
fn gng_parallel_bit_identical_to_multi_for_every_thread_combo() {
    use msgsn::config::{Algorithm, Driver, RunConfig};
    use msgsn::engine::run_convergence;
    use msgsn::som::{Gng, GngParams};

    let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
    let sampler = SurfaceSampler::new(&mesh);
    let mut cfg = RunConfig::preset(BenchmarkShape::Eight);
    cfg.algorithm = Algorithm::Gng;
    cfg.gng = GngParams { lambda: 60, ..cfg.gng };
    cfg.limits.max_signals = 25_000;
    cfg.find_threads = 1;
    cfg.update_threads = 1;

    cfg.driver = Driver::Multi;
    let mut gng_a = Gng::new(cfg.gng);
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(29);
    let a = run_convergence(&mut gng_a, &sampler, &mut fw_a, &cfg, &mut rng_a);

    let mut combos = vec![
        (2usize, 1usize, 1usize),
        (1, 2, 1),
        (3, 7, 1),
        (0, 0, 1),
        // GNG under the region schedule: its inserts stay inline (global
        // error scan), but the region conflict domains and the region
        // Find Winners scan must still be invisible in the results.
        (2, 2, 27),
        (0, 0, 64),
    ];
    combos.extend(env_combo());
    for (update_threads, find_threads, regions) in combos {
        cfg.driver = Driver::Parallel;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.regions = regions;
        let mut gng_b = Gng::new(cfg.gng);
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(29);
        let b = run_convergence(&mut gng_b, &sampler, &mut fw_b, &cfg, &mut rng_b);
        let label = format!("gng upd={update_threads} find={find_threads} regions={regions}");
        assert_eq!(a.iterations, b.iterations, "{label}");
        assert_eq!(a.signals, b.signals, "{label}");
        assert_eq!(a.discarded, b.discarded, "{label}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        assert_networks_identical(gng_a.net(), gng_b.net(), &label);
    }
}

/// Satellite (PR 3, extended in PR 4): the pipelined driver composed with
/// the pooled Update split and the region schedule — the final network
/// must be invariant in `update_threads` AND `regions` for every
/// `queue_depth` (and across queue depths, as before).
#[test]
fn pipelined_bit_identical_across_queue_depth_update_threads_and_regions() {
    use msgsn::coordinator::{run_pipelined, BatchExecutor};
    use msgsn::som::RegionMap;

    let run = |queue_depth: usize, update_threads: usize, regions: usize| -> (Soam, u64, u64) {
        let sampler = blob_sampler();
        let lim = limits(30_000);
        let mut soam = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw = BatchRust::default();
        let mut exec = BatchExecutor::new(update_threads);
        if regions > 1 {
            let map = RegionMap::new(sampler.bounds(), regions);
            fw.attach_regions(map.clone());
            exec.set_regions(map);
        }
        let mut rng = Rng::seed_from(33);
        let r = run_pipelined(&mut soam, &sampler, &mut fw, &lim, &mut rng, queue_depth, exec);
        (soam, r.discarded, r.signals)
    };

    let (ref_soam, ref_disc, ref_sig) = run(2, 1, 1);
    let mut combos = vec![
        (1usize, 2usize, 1usize),
        (2, 3, 1),
        (2, 0, 1),
        (4, 2, 1),
        (2, 3, 27),
        (4, 0, 64),
    ];
    if let Some((upd, _, regions)) = env_combo() {
        let qd = env_knob("MSGSN_TEST_QUEUE_DEPTH").unwrap_or(2);
        combos.push((qd, upd, regions));
    }
    for (queue_depth, update_threads, regions) in combos {
        let (soam, disc, sig) = run(queue_depth, update_threads, regions);
        let label = format!("pipelined qd={queue_depth} upd={update_threads} regions={regions}");
        assert_eq!(ref_disc, disc, "{label}");
        assert_eq!(ref_sig, sig, "{label}");
        assert_networks_identical(ref_soam.net(), soam.net(), &label);
    }
}

/// Acceptance (PR 4): with a region map attached, insertion-class updates
/// flow through the deferred concurrent commit instead of flushing the
/// deferral queue — structural commits no longer serialize the concurrent
/// commit. (Bit-parity of the same configuration is covered by
/// `pooled_plan_and_sharded_find_match_multi_bitwise` above.)
#[test]
fn region_schedule_defers_insert_commits() {
    use msgsn::coordinator::{BatchExecutor, MSchedule};
    use msgsn::som::RegionMap;

    let sampler = blob_sampler();
    let mut soam = Soam::new(SoamParams {
        insertion_threshold: 0.16,
        ..SoamParams::default()
    });
    let mut rng = Rng::seed_from(41);
    soam.init(&sampler, &mut rng);
    let mut fw = BatchRust::default();
    fw.attach_regions(RegionMap::new(sampler.bounds(), 64));
    fw.rebuild(soam.net());
    let mut exec = BatchExecutor::new(4);
    exec.set_regions(RegionMap::new(sampler.bounds(), 64));
    let mut signals = Vec::new();
    let mut winners = Vec::new();
    let schedule = MSchedule::default();
    for _ in 0..300 {
        let m = schedule.m(soam.net().len());
        sampler.sample_batch(&mut rng, m, &mut signals);
        fw.find2_batch(soam.net(), &signals, &mut winners);
        exec.run_batch(&mut soam, &mut fw, &signals, &winners, &mut rng);
    }
    assert!(
        exec.inserts_deferred() > 0,
        "no insert-class update ever took the deferred commit path"
    );
    soam.net().check_invariants().unwrap();
}

/// Acceptance (PR 5): kill-and-resume at random batch boundaries, under
/// random `(regions, update_threads, find_threads)` combos, is
/// bit-identical to the uninterrupted sequential `Multi` reference — for
/// SOAM, GWR and GNG. Every chunk boundary is a kill: the session is
/// serialized, dropped, rebuilt fresh from the config and restored, so the
/// snapshot must carry *everything* (slab + free-list stamps, adjacency
/// order, algorithm scalars, GNG epochs, RNG state, counters).
#[test]
fn snapshot_resume_bit_identical_across_knob_matrix() {
    use msgsn::config::{Algorithm, Driver, RunConfig};
    use msgsn::engine::{make_algorithm, run_convergence, ConvergenceSession};
    use msgsn::fleet::snapshot::{restore_session, snapshot_session};

    let mut chunk_rng = Rng::seed_from(0x5EED_CAFE);
    let mut combos: Vec<(Algorithm, usize, usize, usize)> = vec![
        (Algorithm::Soam, 1, 1, 1),
        (Algorithm::Soam, 3, 2, 27),
        (Algorithm::Gwr, 2, 7, 8),
        (Algorithm::Gng, 0, 0, 64),
    ];
    if let Some((upd, find, regions)) = env_combo() {
        for algorithm in [Algorithm::Soam, Algorithm::Gwr, Algorithm::Gng] {
            combos.push((algorithm, upd, find, regions));
        }
    }
    for (algorithm, update_threads, find_threads, regions) in combos {
        let shape = match algorithm {
            Algorithm::Gng => BenchmarkShape::Eight,
            _ => BenchmarkShape::Blob,
        };
        let mesh = benchmark_mesh(shape, 20);
        let sampler = SurfaceSampler::new(&mesh);
        let mut cfg = RunConfig::preset(shape);
        cfg.algorithm = algorithm;
        cfg.soam.insertion_threshold = 0.16;
        cfg.gwr.insertion_threshold = 0.12;
        cfg.gng.lambda = 60;
        cfg.limits.max_signals = 18_000;
        cfg.seed = 31;

        // Reference: uninterrupted sequential Multi (all knobs off).
        cfg.driver = Driver::Multi;
        cfg.update_threads = 1;
        cfg.find_threads = 1;
        cfg.regions = 1;
        let mut ref_algo = make_algorithm(&cfg);
        let mut ref_fw = BatchRust::default();
        let mut ref_rng = Rng::seed_from(cfg.seed);
        let a = run_convergence(ref_algo.as_mut(), &sampler, &mut ref_fw, &cfg, &mut ref_rng);

        // Session: parallel driver with the combo knobs, killed at every
        // chunk boundary.
        cfg.driver = Driver::Parallel;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        cfg.regions = regions;
        let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        let mut kills = 0u32;
        loop {
            let chunk = chunk_rng.below(15) + 1;
            if !session.step(chunk) {
                break;
            }
            let bytes = snapshot_session(&session);
            drop(session);
            session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
            restore_session(&mut session, &bytes).unwrap();
            kills += 1;
        }
        let b = session.finish();

        let label = format!(
            "{} upd={update_threads} find={find_threads} regions={regions} ({kills} kills)",
            match algorithm {
                Algorithm::Soam => "soam",
                Algorithm::Gwr => "gwr",
                Algorithm::Gng => "gng",
            }
        );
        assert!(kills > 0, "{label}: the kill loop never engaged");
        assert_eq!(a.iterations, b.iterations, "{label}");
        assert_eq!(a.signals, b.signals, "{label}");
        assert_eq!(a.discarded, b.discarded, "{label}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        assert_networks_identical(ref_algo.net(), session.algo().net(), &label);
    }
}

/// Acceptance (PR 5): the pipelined session mode — the synchronous,
/// checkpointable equivalent of the threaded `run_pipelined` driver — is
/// bit-identical to the threaded driver for any `queue_depth`, including
/// under kill/resume (the snapshot carries the forked sampler stream and
/// the one-batch m-schedule lag).
#[test]
fn pipelined_session_resume_matches_threaded_driver() {
    use msgsn::config::{Driver, RunConfig};
    use msgsn::engine::{run_convergence, ConvergenceSession};
    use msgsn::fleet::snapshot::{restore_session, snapshot_session};

    let sampler = blob_sampler();
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 20);
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.driver = Driver::Pipelined;
    cfg.soam.insertion_threshold = 0.16;
    cfg.limits.max_signals = 20_000;
    cfg.seed = 33;
    cfg.queue_depth = env_knob("MSGSN_TEST_QUEUE_DEPTH").unwrap_or(2);
    if let Some((upd, find, regions)) = env_combo() {
        cfg.update_threads = upd;
        cfg.find_threads = find;
        cfg.regions = regions;
    } else {
        cfg.update_threads = 2;
        cfg.find_threads = 1;
        cfg.regions = 8;
    }

    // Threaded reference (sampler thread + bounded channels).
    let mut soam_a = Soam::new(SoamParams {
        insertion_threshold: 0.16,
        ..SoamParams::default()
    });
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(cfg.seed);
    let a = run_convergence(&mut soam_a, &sampler, &mut fw_a, &cfg, &mut rng_a);

    // Synchronous session, killed every few batches.
    let mut session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
    let mut chunk_rng = Rng::seed_from(0xF1EE7);
    while session.step(chunk_rng.below(9) + 1) {
        let bytes = snapshot_session(&session);
        session = ConvergenceSession::new(&cfg, &mesh, None).unwrap();
        restore_session(&mut session, &bytes).unwrap();
    }
    let b = session.finish();

    assert_eq!(a.iterations, b.iterations, "pipelined session vs threaded");
    assert_eq!(a.signals, b.signals);
    assert_eq!(a.discarded, b.discarded);
    assert_eq!(a.qe.to_bits(), b.qe.to_bits());
    assert_networks_identical(soam_a.net(), session.algo().net(), "pipelined session");
}

/// Acceptance (PR 6): the SIMD Find-Winners dispatch is invisible in the
/// results — a full convergence run with the `fw_isa` knob forcing the
/// portable fallback tier is bit-identical to the same run on the
/// auto-detected best tier (AVX-512/AVX2/NEON where the host supports
/// one). Both runs construct their scanner through `make_findwinners`,
/// the same chokepoint the CLI, sessions and fleet jobs use, so the knob
/// path itself is under test. The CI matrix additionally re-runs the
/// whole suite with `MSGSN_FW_ISA=fallback` and with
/// `-C target-cpu=native` (see .github/workflows/ci.yml).
#[test]
fn fw_isa_fallback_matches_dispatched_best_tier() {
    use msgsn::config::{Driver, RunConfig};
    use msgsn::engine::{make_findwinners, run_convergence};
    use msgsn::findwinners::{simd, FwIsa};

    let sampler = blob_sampler();
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.driver = Driver::Multi;
    cfg.soam.insertion_threshold = 0.16;
    cfg.limits.max_signals = 25_000;
    cfg.seed = 17;

    let mut run = |fw_isa: Option<FwIsa>| -> (Soam, u64, u64, u64, u32) {
        cfg.fw_isa = fw_isa;
        let mut soam = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw = make_findwinners(&cfg).unwrap();
        let mut rng = Rng::seed_from(cfg.seed);
        let r = run_convergence(&mut soam, &sampler, fw.as_mut(), &cfg, &mut rng);
        (soam, r.iterations, r.signals, r.discarded, r.qe.to_bits())
    };

    // Check resolution through set_override's RETURN VALUE, not through a
    // later read of the process-global dispatch state: other tests in this
    // binary build sessions that re-resolve the global concurrently. That
    // concurrent re-resolution is harmless precisely because every tier is
    // bit-identical — which is what the assertions below demonstrate.
    assert_eq!(simd::set_override(Some(FwIsa::Fallback)).unwrap(), FwIsa::Fallback);
    let best = simd::set_override(None).unwrap();
    println!("fw_isa parity: fallback vs dispatched {}", best.name());

    let (soam_a, it_a, sig_a, disc_a, qe_a) = run(Some(FwIsa::Fallback));
    let (soam_b, it_b, sig_b, disc_b, qe_b) = run(None);

    let label = format!("fw_isa fallback vs {}", best.name());
    assert_eq!(it_a, it_b, "{label}: iterations");
    assert_eq!(sig_a, sig_b, "{label}: signals");
    assert_eq!(disc_a, disc_b, "{label}: discarded");
    assert_eq!(qe_a, qe_b, "{label}: qe bits");
    assert_networks_identical(soam_a.net(), soam_b.net(), &label);
}

#[test]
fn parallel_matches_multi_for_gwr() {
    let sampler = blob_sampler();
    let lim = limits(25_000);

    let mut gwr_a = Gwr::new(GwrParams {
        insertion_threshold: 0.12,
        ..GwrParams::default()
    });
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(4);
    let a = run_multi_signal(&mut gwr_a, &sampler, &mut fw_a, &lim, &mut rng_a);

    let mut gwr_b = Gwr::new(GwrParams {
        insertion_threshold: 0.12,
        ..GwrParams::default()
    });
    let mut fw_b = BatchRust::default();
    let mut rng_b = Rng::seed_from(4);
    let b = run_parallel(&mut gwr_b, &sampler, &mut fw_b, &lim, &mut rng_b, 3);

    assert_eq!(a.discarded, b.discarded);
    assert_eq!(a.qe.to_bits(), b.qe.to_bits());
    assert_networks_identical(gwr_a.net(), gwr_b.net(), "gwr: parallel vs multi");

    // PR 4: the GWR-specific deferred-insert path — `begin_insert` with the
    // *global* insertion threshold (every other region combo in this suite
    // runs SOAM, whose per-unit-threshold branch is the other half).
    use msgsn::config::{Driver, RunConfig};
    use msgsn::engine::run_convergence;
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.gwr.insertion_threshold = 0.12;
    cfg.driver = Driver::Parallel;
    cfg.update_threads = 3;
    cfg.find_threads = 2;
    cfg.regions = 27;
    cfg.limits = lim;
    let mut gwr_c = Gwr::new(cfg.gwr);
    let mut fw_c = BatchRust::default();
    let mut rng_c = Rng::seed_from(4);
    let c = run_convergence(&mut gwr_c, &sampler, &mut fw_c, &cfg, &mut rng_c);

    assert_eq!(a.discarded, c.discarded, "gwr regions");
    assert_eq!(a.qe.to_bits(), c.qe.to_bits(), "gwr regions");
    assert_networks_identical(gwr_a.net(), gwr_c.net(), "gwr: regions vs multi");
}
