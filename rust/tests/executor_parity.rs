//! Refactor-parity tests for the unified batch-update executor.
//!
//! `reference_multi_signal` below is a line-for-line copy of the
//! pre-refactor `engine::run_multi_signal` loop (per-signal winner locks,
//! linear-scan staleness guard, one `fw.sync` per applied signal) — kept
//! here as the executable specification. The refactored drivers must
//! reproduce it bit-for-bit:
//!
//! - `Driver::Multi` through the shared `BatchExecutor` (merged per-batch
//!   sync, AABB-early-exit staleness guard) must match the reference on
//!   every unit position, firing level, edge and report counter;
//! - `Driver::Parallel` must match `Driver::Multi` for any
//!   `update_threads`, including auto-detect — for SOAM, GWR **and GNG**
//!   (possible since PR 3's lazy error decay; the GNG case covers the
//!   pending-aware insertion-schedule classification, the concurrent
//!   commit, and deterministic slab-id assignment on the sharded free
//!   lists);
//! - `Driver::Pipelined` must be invariant in `update_threads` for any
//!   `queue_depth` (the prefetch composed with the pooled Update split).

use msgsn::config::Limits;
use msgsn::coordinator::LockTable;
use msgsn::engine::{m_schedule, run_multi_signal, run_parallel};
use msgsn::findwinners::{BatchRust, FindWinners};
use msgsn::geometry::Vec3;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::som::{
    ChangeLog, GrowingNetwork, Gwr, GwrParams, Network, Soam, SoamParams, Winners,
};

/// The pre-refactor multi-signal driver loop, verbatim (modulo the report
/// struct: we only track the counters the assertions need).
#[allow(clippy::too_many_lines)]
fn reference_multi_signal(
    algo: &mut dyn GrowingNetwork,
    sampler: &SurfaceSampler,
    fw: &mut dyn FindWinners,
    limits: &Limits,
    rng: &mut Rng,
) -> (u64, u64, u64) {
    let mut log = ChangeLog::default();
    algo.init(sampler, rng);
    fw.rebuild(algo.net());

    let mut signals: Vec<Vec3> = Vec::new();
    let mut winners: Vec<Option<Winners>> = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    let mut locks = LockTable::new();
    let mut batch_inserted: Vec<Vec3> = Vec::new();

    let (mut iterations, mut total_signals, mut discarded) = (0u64, 0u64, 0u64);
    loop {
        iterations += 1;
        let m = m_schedule(algo.net().len(), limits.max_parallelism);

        sampler.sample_batch(rng, m, &mut signals);
        fw.find2_batch(algo.net(), &signals, &mut winners);

        rng.permutation(m, &mut order);
        locks.next_batch();
        locks.ensure_capacity(algo.net().capacity());
        batch_inserted.clear();
        for &j in &order {
            let w = match winners[j as usize] {
                Some(w) => w,
                None => {
                    discarded += 1;
                    continue;
                }
            };
            let signal = signals[j as usize];
            if !algo.net().is_alive(w.w1)
                || !algo.net().is_alive(w.w2)
                || batch_inserted.iter().any(|p| signal.dist2(*p) < w.d1_sq)
                || !locks.try_lock(w.w1)
            {
                discarded += 1;
                continue;
            }
            log.clear();
            algo.update(signal, &w, &mut log);
            for &id in &log.inserted {
                batch_inserted.push(algo.net().pos(id));
            }
            fw.sync(algo.net(), &log);
        }
        total_signals += m as u64;

        log.clear();
        let converged = algo.housekeeping(&mut log);
        if !log.is_empty() {
            fw.sync(algo.net(), &log);
        }
        if converged {
            break;
        }
        if total_signals >= limits.max_signals {
            break;
        }
    }
    (iterations, total_signals, discarded)
}

/// Bitwise network equality: slab layout, aliveness, positions, firing,
/// error, thresholds and the full aged edge sets.
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let mut ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let mut eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        ea.sort_unstable();
        eb.sort_unstable();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

fn limits(max_signals: u64) -> Limits {
    Limits { max_signals, ..Limits::default() }
}

fn blob_sampler() -> SurfaceSampler {
    SurfaceSampler::new(&benchmark_mesh(BenchmarkShape::Blob, 20))
}

#[test]
fn multi_through_executor_matches_pre_refactor_reference() {
    for seed in [1u64, 9, 42] {
        let sampler = blob_sampler();
        let lim = limits(30_000);

        let mut soam_a = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_a = BatchRust::default();
        let mut rng_a = Rng::seed_from(seed);
        let (it_a, sig_a, disc_a) =
            reference_multi_signal(&mut soam_a, &sampler, &mut fw_a, &lim, &mut rng_a);

        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(seed);
        let r = run_multi_signal(&mut soam_b, &sampler, &mut fw_b, &lim, &mut rng_b);

        assert_eq!(it_a, r.iterations, "seed {seed}: iterations");
        assert_eq!(sig_a, r.signals, "seed {seed}: signals");
        assert_eq!(disc_a, r.discarded, "seed {seed}: discarded");
        assert_networks_identical(
            soam_a.net(),
            soam_b.net(),
            &format!("seed {seed}: multi vs reference"),
        );
    }
}

#[test]
fn parallel_matches_multi_for_every_thread_count() {
    for (seed, threads) in [(7u64, 1usize), (7, 2), (7, 4), (7, 0), (21, 3)] {
        let sampler = blob_sampler();
        let lim = limits(30_000);

        let mut soam_a = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_a = BatchRust::default();
        let mut rng_a = Rng::seed_from(seed);
        let a = run_multi_signal(&mut soam_a, &sampler, &mut fw_a, &lim, &mut rng_a);

        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(seed);
        let b = run_parallel(&mut soam_b, &sampler, &mut fw_b, &lim, &mut rng_b, threads);

        assert_eq!(a.iterations, b.iterations, "seed {seed} threads {threads}");
        assert_eq!(a.signals, b.signals, "seed {seed} threads {threads}");
        assert_eq!(a.discarded, b.discarded, "seed {seed} threads {threads}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "seed {seed} threads {threads}: qe");
        assert_networks_identical(
            soam_a.net(),
            soam_b.net(),
            &format!("seed {seed} threads {threads}: parallel vs multi"),
        );
    }
}

#[test]
fn pooled_plan_and_sharded_find_match_multi_bitwise() {
    // The full engine path: one shared worker pool per run (created in
    // run_convergence), plan pass pooled, Find Winners sharded — the final
    // network must still match the sequential multi driver bit-for-bit for
    // every (update_threads, find_threads) combination.
    use msgsn::config::{Driver, RunConfig};
    use msgsn::engine::run_convergence;

    let sampler = blob_sampler();
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.soam.insertion_threshold = 0.16;
    cfg.limits.max_signals = 30_000;

    let mut soam_a = Soam::new(SoamParams {
        insertion_threshold: 0.16,
        ..SoamParams::default()
    });
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(15);
    let a = run_multi_signal(&mut soam_a, &sampler, &mut fw_a, &cfg.limits, &mut rng_a);

    for (update_threads, find_threads) in [(1usize, 2usize), (3, 7), (2, 2), (0, 0)] {
        cfg.driver = Driver::Parallel;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        let mut soam_b = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(15);
        let b = run_convergence(&mut soam_b, &sampler, &mut fw_b, &cfg, &mut rng_b);
        let label = format!("upd={update_threads} find={find_threads}");
        assert_eq!(a.iterations, b.iterations, "{label}");
        assert_eq!(a.signals, b.signals, "{label}");
        assert_eq!(a.discarded, b.discarded, "{label}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        assert_networks_identical(soam_a.net(), soam_b.net(), &label);
    }
}

/// Acceptance (PR 3): GNG under the `Parallel` driver is bit-identical to
/// the sequential `Multi` driver for any `(update_threads, find_threads)`
/// — including unit ids (deterministic shard-local allocation) and the
/// lazily decayed per-unit errors (when a unit materializes is itself part
/// of the deterministic operation sequence, so the stored error bits and
/// epoch stamps match across drivers without any final sweep).
#[test]
fn gng_parallel_bit_identical_to_multi_for_every_thread_combo() {
    use msgsn::config::{Algorithm, Driver, RunConfig};
    use msgsn::engine::run_convergence;
    use msgsn::som::{Gng, GngParams};

    let mesh = benchmark_mesh(BenchmarkShape::Eight, 20);
    let sampler = SurfaceSampler::new(&mesh);
    let mut cfg = RunConfig::preset(BenchmarkShape::Eight);
    cfg.algorithm = Algorithm::Gng;
    cfg.gng = GngParams { lambda: 60, ..cfg.gng };
    cfg.limits.max_signals = 25_000;
    cfg.find_threads = 1;
    cfg.update_threads = 1;

    cfg.driver = Driver::Multi;
    let mut gng_a = Gng::new(cfg.gng);
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(29);
    let a = run_convergence(&mut gng_a, &sampler, &mut fw_a, &cfg, &mut rng_a);

    for (update_threads, find_threads) in [(2usize, 1usize), (1, 2), (3, 7), (0, 0)] {
        cfg.driver = Driver::Parallel;
        cfg.update_threads = update_threads;
        cfg.find_threads = find_threads;
        let mut gng_b = Gng::new(cfg.gng);
        let mut fw_b = BatchRust::default();
        let mut rng_b = Rng::seed_from(29);
        let b = run_convergence(&mut gng_b, &sampler, &mut fw_b, &cfg, &mut rng_b);
        let label = format!("gng upd={update_threads} find={find_threads}");
        assert_eq!(a.iterations, b.iterations, "{label}");
        assert_eq!(a.signals, b.signals, "{label}");
        assert_eq!(a.discarded, b.discarded, "{label}");
        assert_eq!(a.qe.to_bits(), b.qe.to_bits(), "{label}");
        assert_networks_identical(gng_a.net(), gng_b.net(), &label);
    }
}

/// Satellite (PR 3): the pipelined driver composed with the pooled Update
/// split — the final network must be invariant in `update_threads` for
/// every `queue_depth` (and across queue depths, as before).
#[test]
fn pipelined_bit_identical_across_queue_depth_and_update_threads() {
    use msgsn::coordinator::{run_pipelined, BatchExecutor};

    let run = |queue_depth: usize, update_threads: usize| -> (Soam, u64, u64) {
        let sampler = blob_sampler();
        let lim = limits(30_000);
        let mut soam = Soam::new(SoamParams {
            insertion_threshold: 0.16,
            ..SoamParams::default()
        });
        let mut fw = BatchRust::default();
        let mut rng = Rng::seed_from(33);
        let r = run_pipelined(
            &mut soam,
            &sampler,
            &mut fw,
            &lim,
            &mut rng,
            queue_depth,
            BatchExecutor::new(update_threads),
        );
        (soam, r.discarded, r.signals)
    };

    let (ref_soam, ref_disc, ref_sig) = run(2, 1);
    for (queue_depth, update_threads) in [(1usize, 2usize), (2, 3), (2, 0), (4, 2)] {
        let (soam, disc, sig) = run(queue_depth, update_threads);
        let label = format!("pipelined qd={queue_depth} upd={update_threads}");
        assert_eq!(ref_disc, disc, "{label}");
        assert_eq!(ref_sig, sig, "{label}");
        assert_networks_identical(ref_soam.net(), soam.net(), &label);
    }
}

#[test]
fn parallel_matches_multi_for_gwr() {
    let sampler = blob_sampler();
    let lim = limits(25_000);

    let mut gwr_a = Gwr::new(GwrParams {
        insertion_threshold: 0.12,
        ..GwrParams::default()
    });
    let mut fw_a = BatchRust::default();
    let mut rng_a = Rng::seed_from(4);
    let a = run_multi_signal(&mut gwr_a, &sampler, &mut fw_a, &lim, &mut rng_a);

    let mut gwr_b = Gwr::new(GwrParams {
        insertion_threshold: 0.12,
        ..GwrParams::default()
    });
    let mut fw_b = BatchRust::default();
    let mut rng_b = Rng::seed_from(4);
    let b = run_parallel(&mut gwr_b, &sampler, &mut fw_b, &lim, &mut rng_b, 3);

    assert_eq!(a.discarded, b.discarded);
    assert_eq!(a.qe.to_bits(), b.qe.to_bits());
    assert_networks_identical(gwr_a.net(), gwr_b.net(), "gwr: parallel vs multi");
}
