//! Serve integration tests — the PR 9 acceptance points, end to end over
//! real TCP (real listener, real line protocol, the same `Fleet` as the
//! batch path):
//!
//! - **serve ≡ batch**: jobs submitted over the wire to a running daemon
//!   finish **bit-identical** to the same manifest run via `fleet::Fleet`
//!   (the daemon adds a protocol, not state);
//! - **query non-perturbation**: a client hammering `status` / `query`
//!   (units, mesh extraction, snapshot CRC) against a converging job
//!   leaves the final network — and the full encoded session — bitwise
//!   unchanged versus an unobserved run;
//! - **chaos**: a `serve_conn:drop@2` injection that severs a client
//!   mid-conversation kills neither the daemon nor its jobs; the client
//!   reconnects, resubmission is answered with the idempotent `exists`
//!   code, and parity still holds.
//!
//! Every test holds the fault test lock: the chaos test arms an unscoped
//! `serve_conn` spec that would otherwise be consumed by a concurrently
//! running sibling's connections, and the parity tests clear the profile
//! because a dropped test client (no reconnect logic) is exactly what
//! they are *not* about — the CI `serve-e2e` chaos cell drives the real
//! daemon under `MSGSN_FAULTS` instead.

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use msgsn::fleet::snapshot::snapshot_session;
use msgsn::fleet::{parse_manifest, Fleet, FleetOptions, FleetOutcome};
use msgsn::runtime::fault;
use msgsn::runtime::{parse_json, Json};
use msgsn::serve::{ServeOptions, Server};
use msgsn::som::Network;

/// Bitwise network equality (same contract as the fleet/dist suites).
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

/// One inline manifest-job object — the same text is submitted over the
/// wire and assembled into the reference manifest, so both paths parse
/// byte-identical specs.
fn job_row(name: &str, seed: u64) -> String {
    format!(
        r#"{{"name": "{name}", "mesh": "blob", "algorithm": "soam", "driver": "multi",
             "seed": {seed},
             "config": {{"mesh_resolution": 16, "insertion_threshold": 0.2,
                         "max_signals": 4000}}}}"#
    )
}

fn manifest(jobs: &[(&str, u64)]) -> String {
    let rows: Vec<String> = jobs.iter().map(|(n, s)| job_row(n, s)).collect();
    format!(r#"{{"version": 1, "jobs": [{}]}}"#, rows.join(","))
}

/// The undisturbed batch reference: the same manifest through
/// `fleet::Fleet` — what every serve run must be bit-identical to.
fn reference_fleet(text: &str) -> Fleet {
    let specs = parse_manifest(text).unwrap();
    let mut fleet = Fleet::new(specs).unwrap();
    fleet.run(&FleetOptions::default(), |_| {}).unwrap();
    fleet
}

fn job_net<'a>(fleet: &'a Fleet, name: &str) -> &'a Network {
    fleet
        .jobs()
        .iter()
        .find(|j| j.spec().name == name)
        .unwrap_or_else(|| panic!("no job {name:?} in fleet"))
        .session()
        .unwrap_or_else(|| panic!("job {name:?} has no session"))
        .algo()
        .net()
}

/// Start a daemon on an ephemeral port; the thread returns the drained
/// server (for post-run parity assertions) and its final report.
fn spawn_server() -> (SocketAddr, std::thread::JoinHandle<(Server, msgsn::fleet::FleetReport)>) {
    let mut server = Server::bind("127.0.0.1:0", Vec::new()).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::Builder::new()
        .name("msgsn-serve".to_string())
        .spawn(move || {
            let opts = ServeOptions {
                idle_poll: Duration::from_millis(1),
                watch_every: 4,
                ..ServeOptions::default()
            };
            let report = server.run(&opts, |_| {}).unwrap();
            (server, report)
        })
        .unwrap();
    (addr, handle)
}

/// A deliberately simple blocking line client: the daemon under test is
/// the non-blocking side.
struct LineClient {
    reader: BufReader<TcpStream>,
}

impl LineClient {
    fn connect(addr: SocketAddr) -> LineClient {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        LineClient { reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        let s = self.reader.get_mut();
        s.write_all(line.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }

    /// Next line as JSON; `None` on EOF (the daemon closed us).
    fn recv(&mut self) -> Option<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => None,
            Ok(_) => Some(parse_json(line.trim()).unwrap_or_else(|e| {
                panic!("daemon sent invalid JSON {line:?}: {e}")
            })),
            Err(e) => panic!("reading from daemon: {e}"),
        }
    }

    /// Send a request and read to its response (an `"ok"`-keyed object),
    /// routing interleaved `"event"` lines into `events`. `None` on EOF.
    fn request(&mut self, line: &str, events: &mut Vec<Json>) -> Option<Json> {
        self.send(line);
        loop {
            let doc = self.recv()?;
            if doc.get("ok").is_some() {
                return Some(doc);
            }
            events.push(doc);
        }
    }
}

fn assert_ok(resp: &Json, label: &str) {
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "{label}: {resp:?}"
    );
}

fn event_name(doc: &Json) -> Option<&str> {
    doc.get("event").and_then(Json::as_str)
}

/// Drive a shutdown-initiated drain to the `bye` event, returning every
/// event seen since `events` (done/progress/report/bye).
fn drain_to_bye(client: &mut LineClient, events: &mut Vec<Json>) {
    loop {
        let doc = client.recv().expect("daemon hung up before bye");
        let done = event_name(&doc) == Some("bye");
        events.push(doc);
        if done {
            return;
        }
    }
}

#[test]
fn serve_path_is_bit_identical_to_batch_path() {
    let _guard = fault::test_lock();
    fault::clear();
    let jobs = [("sv-par-a", 41u64), ("sv-par-b", 42u64)];
    let reference = reference_fleet(&manifest(&jobs));

    let (addr, handle) = spawn_server();
    let mut client = LineClient::connect(addr);
    let mut events = Vec::new();
    let watch = client.request(r#"{"cmd": "watch"}"#, &mut events).unwrap();
    assert_ok(&watch, "watch");
    for (name, seed) in jobs {
        let resp = client
            .request(&format!(r#"{{"cmd": "submit", "job": {}}}"#, job_row(name, seed)), &mut events)
            .unwrap();
        assert_ok(&resp, "submit");
        assert_eq!(resp.get("job").and_then(Json::as_str), Some(name));
    }
    let resp = client.request(r#"{"cmd": "shutdown"}"#, &mut events).unwrap();
    assert_ok(&resp, "shutdown");
    drain_to_bye(&mut client, &mut events);

    // The stream announced both completions, streamed progress, and
    // carried the final report + exit code.
    let done: BTreeSet<&str> = events
        .iter()
        .filter(|e| event_name(e) == Some("done"))
        .filter_map(|e| e.get("job").and_then(|j| j.get("name")).and_then(Json::as_str))
        .collect();
    assert_eq!(done, jobs.iter().map(|(n, _)| *n).collect::<BTreeSet<_>>());
    assert!(
        events.iter().any(|e| event_name(e) == Some("progress")),
        "no progress events were streamed"
    );
    let bye = events.iter().find(|e| event_name(e) == Some("bye")).unwrap();
    assert_eq!(bye.get("exit").and_then(Json::as_u64), Some(0));
    let report_ev = events.iter().find(|e| event_name(e) == Some("report")).unwrap();
    assert_eq!(
        report_ev.get("rows").and_then(Json::as_arr).map(Vec::len),
        Some(jobs.len())
    );

    let (server, report) = handle.join().unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
    for (name, _) in jobs {
        assert_networks_identical(
            job_net(server.fleet(), name),
            job_net(&reference, name),
            name,
        );
    }
}

#[test]
fn query_during_convergence_does_not_perturb() {
    let _guard = fault::test_lock();
    fault::clear();
    let name = "sv-query";
    let reference = reference_fleet(&manifest(&[(name, 77)]));

    let (addr, handle) = spawn_server();
    let mut client = LineClient::connect(addr);
    let mut events = Vec::new();
    let resp = client
        .request(&format!(r#"{{"cmd": "submit", "job": {}}}"#, job_row(name, 77)), &mut events)
        .unwrap();
    assert_ok(&resp, "submit");

    // Hammer the read surface while the job converges: every batch
    // boundary the daemon reaches may serve a units / mesh / snapshot
    // view. If read views perturbed anything, the final bits would drift.
    let mut views = 0usize;
    loop {
        for what in ["units", "mesh", "snapshot"] {
            let q = client
                .request(
                    &format!(r#"{{"cmd": "query", "job": "{name}", "what": "{what}"}}"#),
                    &mut events,
                )
                .unwrap();
            assert_ok(&q, "query");
            assert!(q.get("view").is_some(), "query carried no view: {q:?}");
            views += 1;
        }
        let status = client.request(r#"{"cmd": "status"}"#, &mut events).unwrap();
        assert_ok(&status, "status");
        let rows = status.get("jobs").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        let done = rows[0].get("status").and_then(Json::as_str) == Some("done");
        if done {
            break;
        }
    }
    assert!(views >= 3, "the queries never ran");
    let resp = client.request(r#"{"cmd": "shutdown"}"#, &mut events).unwrap();
    assert_ok(&resp, "shutdown");
    drain_to_bye(&mut client, &mut events);

    let (server, report) = handle.join().unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
    assert_networks_identical(job_net(server.fleet(), name), job_net(&reference, name), name);
    // Stronger than the network: the complete encoded session (RNG
    // streams, counters, index) is byte-identical to the unobserved run.
    let observed = server.fleet().jobs()[0].session().unwrap();
    let unobserved = reference.jobs()[0].session().unwrap();
    assert_eq!(
        snapshot_session(observed),
        snapshot_session(unobserved),
        "read views perturbed the encoded session"
    );
}

#[test]
fn dropped_client_kills_neither_daemon_nor_jobs() {
    let _guard = fault::test_lock();
    fault::install(fault::parse_faults("serve_conn:drop@2").unwrap());
    let name = "sv-chaos";
    let reference = reference_fleet(&manifest(&[(name, 91)]));

    let (addr, handle) = spawn_server();
    let mut client = LineClient::connect(addr);
    let mut events = Vec::new();
    let resp = client
        .request(&format!(r#"{{"cmd": "submit", "job": {}}}"#, job_row(name, 91)), &mut events)
        .unwrap();
    assert_ok(&resp, "submit");
    // Second request trips the injected drop: the daemon discards it and
    // severs the connection. The client observes EOF, nothing more.
    let severed = client.request(r#"{"cmd": "status"}"#, &mut events);
    assert!(severed.is_none(), "injected drop did not sever the connection: {severed:?}");

    // Reconnect; the daemon is alive and the job kept converging.
    let mut client = LineClient::connect(addr);
    let status = client.request(r#"{"cmd": "status"}"#, &mut events).unwrap();
    assert_ok(&status, "status after reconnect");
    // Idempotent resubmission: answered with the `exists` code, not an
    // error that would make a retrying client give up.
    let resub = client
        .request(&format!(r#"{{"cmd": "submit", "job": {}}}"#, job_row(name, 91)), &mut events)
        .unwrap();
    assert_eq!(resub.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(resub.get("code").and_then(Json::as_str), Some("exists"));

    let resp = client.request(r#"{"cmd": "shutdown"}"#, &mut events).unwrap();
    assert_ok(&resp, "shutdown");
    drain_to_bye(&mut client, &mut events);

    let (server, report) = handle.join().unwrap();
    assert_eq!(report.outcome(), FleetOutcome::AllSucceeded);
    assert_networks_identical(job_net(server.fleet(), name), job_net(&reference, name), name);
}
