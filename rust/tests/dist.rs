//! Dist integration tests — the PR 8 acceptance points, end to end over
//! the in-process transport (real threads, real protocol, real fleet
//! scheduling — only the pipe is a channel instead of a socket):
//!
//! - **baseline**: a coordinator + N worker threads complete a manifest
//!   with every final network **bit-identical** to a single-process fleet
//!   run of the same manifest (the distribution layer adds routing, not
//!   state);
//! - **worker kill**: a worker killed by an injected panic at an
//!   arbitrary scheduler round has its jobs migrated to a survivor from
//!   the last shipped checkpoint generation — finals still bit-identical
//!   to the undisturbed run;
//! - **hung worker**: a worker that stalls (injected delay) without dying
//!   is evicted on the heartbeat timeout and its jobs complete elsewhere,
//!   with no deadlock — and the woken zombie is partition-safe (never
//!   polled again);
//! - **all workers dead**: documented non-zero exit (code 4,
//!   `DistOutcome::WorkersLost`) instead of a hang;
//! - **lossy links**: deterministic dropped/duplicated frames are
//!   absorbed by the seq/ack/retransmission discipline.
//!
//! The CI chaos matrix cell re-runs this suite single-threaded under
//! `MSGSN_FAULTS="transport_recv:drop@turn=32,worker:panic@2"` — every
//! recovery path is *transparent*, so the same assertions must hold with
//! the unscoped chaos profile armed (tests that install their own scoped
//! specs hold the fault test lock, which suspends the env profile for
//! their duration and re-arms it after).

use std::time::Duration;

use msgsn::dist::{
    channel_transport_pair, run_worker, Coordinator, DistJobStatus, DistOptions, DistOutcome,
    WorkerOptions,
};
use msgsn::engine::ConvergenceSession;
use msgsn::fleet::snapshot::restore_session;
use msgsn::fleet::{manifest_job_payloads, parse_manifest, Fleet, FleetOptions, JobSpec};
use msgsn::runtime::fault;
use msgsn::som::Network;

/// Bitwise network equality (same contract as the fleet suite's helper).
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

/// A small-jobs manifest (tiny mesh, few signals): the suite restores
/// sessions and runs reference fleets repeatedly, so job size is the
/// suite's wall-clock.
fn manifest(jobs: &[(&str, u64)]) -> String {
    let rows: Vec<String> = jobs
        .iter()
        .map(|(name, seed)| {
            format!(
                r#"{{"name": "{name}", "mesh": "blob", "algorithm": "soam", "driver": "multi",
                     "seed": {seed},
                     "config": {{"mesh_resolution": 16, "insertion_threshold": 0.2,
                                 "max_signals": 4000}}}}"#
            )
        })
        .collect();
    format!(r#"{{"version": 1, "jobs": [{}]}}"#, rows.join(","))
}

/// The undisturbed single-process reference: the same manifest through
/// `fleet::Fleet` — what every dist run must be bit-identical to.
fn reference_fleet(text: &str) -> Fleet {
    let specs = parse_manifest(text).unwrap();
    let mut fleet = Fleet::new(specs).unwrap();
    fleet.run(&FleetOptions::default(), |_| {}).unwrap();
    fleet
}

/// Restore a final snapshot shipped over the wire into a fresh session.
fn restored_session(spec: &JobSpec, bytes: &[u8]) -> ConvergenceSession {
    let mesh = spec.build_mesh().unwrap();
    let mut s = ConvergenceSession::new(&spec.cfg, &mesh, None).unwrap();
    restore_session(&mut s, bytes).unwrap_or_else(|e| panic!("restoring {}: {e}", spec.name));
    s
}

/// Spawn one worker thread per name over in-process links, registering
/// the coordinator ends. Worker names double as fault scopes — each test
/// uses unique names so scoped specs can never leak across tests.
fn spawn_workers(
    coordinator: &mut Coordinator,
    names: &[&str],
    checkpoint_rounds: u64,
) -> Vec<std::thread::JoinHandle<Result<(), String>>> {
    names
        .iter()
        .map(|name| {
            let (coord_end, mut worker_end) = channel_transport_pair(name);
            coordinator.add_worker(name, Box::new(coord_end));
            let opts = WorkerOptions {
                name: name.to_string(),
                stride: 1,
                checkpoint_rounds,
                idle_poll: Duration::from_millis(2),
            };
            std::thread::Builder::new()
                .name(format!("msgsn-{name}"))
                .spawn(move || run_worker(&mut worker_end, &opts, |_| {}))
                .unwrap()
        })
        .collect()
}

/// Assert every job finished and is bit-identical to the single-process
/// reference run of the same manifest.
fn assert_bit_exact(coordinator: &Coordinator, text: &str) {
    let reference = reference_fleet(text);
    let specs = parse_manifest(text).unwrap();
    for (k, spec) in specs.iter().enumerate() {
        let bytes = coordinator
            .final_snapshot(&spec.name)
            .unwrap_or_else(|| panic!("no final snapshot for {}", spec.name));
        let restored = restored_session(spec, bytes);
        assert_networks_identical(
            reference.jobs()[k].session().unwrap().algo().net(),
            restored.algo().net(),
            &spec.name,
        );
    }
}

#[test]
fn dist_fleet_matches_single_process_fleet() {
    let text = manifest(&[("dj-a", 11), ("dj-b", 12), ("dj-c", 13)]);
    let mut coordinator = Coordinator::new(
        manifest_job_payloads(&text).unwrap(),
        DistOptions { heartbeat_timeout: Duration::from_secs(30), ..DistOptions::default() },
    );
    let workers = spawn_workers(&mut coordinator, &["zz-dist-base-w0", "zz-dist-base-w1"], 4);
    let report = coordinator.run(|_| {});
    assert_eq!(report.outcome(), DistOutcome::AllDone, "{report:?}");
    assert_eq!(report.outcome().exit_code(), 0);
    for row in &report.rows {
        assert_eq!(row.status, DistJobStatus::Done, "{row:?}");
    }
    // Progress is fire-and-forget, so under the CI chaos profile a single
    // counter update may be lost — but not every one of them.
    assert!(report.rows.iter().any(|r| r.signals > 0), "progress counters flowed: {report:?}");
    for w in workers {
        let _ = w.join();
    }
    assert_bit_exact(&coordinator, &text);
}

#[test]
fn worker_kill_migrates_jobs_bit_exactly() {
    let _guard = fault::test_lock();
    // Kill w1 at its 6th scheduler round — mid-job, after it has shipped
    // at least two periodic checkpoint generations (cadence 2).
    fault::install(fault::parse_faults("worker/zz-dist-kill-w1:panic@turn=6").unwrap());
    let text = manifest(&[("dk-a", 21), ("dk-b", 22)]);
    let mut coordinator = Coordinator::new(
        manifest_job_payloads(&text).unwrap(),
        DistOptions { heartbeat_timeout: Duration::from_secs(30), ..DistOptions::default() },
    );
    let workers = spawn_workers(&mut coordinator, &["zz-dist-kill-w0", "zz-dist-kill-w1"], 2);
    let report = coordinator.run(|_| {});
    assert_eq!(report.outcome(), DistOutcome::AllDone, "{report:?}");
    assert!(
        report.rows.iter().any(|r| r.migrations >= 1),
        "the killed worker's job must have migrated: {report:?}"
    );
    for w in workers {
        let _ = w.join(); // w1's thread died on the injected panic
    }
    assert_bit_exact(&coordinator, &text);
}

#[test]
fn hung_worker_is_evicted_and_jobs_complete_elsewhere() {
    let _guard = fault::test_lock();
    // w0 stalls for 1.5s at its 3rd round — alive but silent far past the
    // 250ms heartbeat window. Eviction must migrate its job and the run
    // must terminate (no deadlock); the woken zombie keeps computing into
    // a link nobody reads (partition safety) until the final Shutdown.
    fault::install(fault::parse_faults("worker/zz-dist-hang-w0:delay=1500@turn=3").unwrap());
    let text = manifest(&[("dh-a", 31), ("dh-b", 32)]);
    let mut coordinator = Coordinator::new(
        manifest_job_payloads(&text).unwrap(),
        DistOptions {
            heartbeat_timeout: Duration::from_millis(250),
            ..DistOptions::default()
        },
    );
    let workers = spawn_workers(&mut coordinator, &["zz-dist-hang-w0", "zz-dist-hang-w1"], 2);
    let mut lines = Vec::new();
    let report = coordinator.run(|l| lines.push(l.to_string()));
    assert_eq!(report.outcome(), DistOutcome::AllDone, "{report:?}\n{lines:?}");
    assert!(
        lines.iter().any(|l| l.contains("evicted: heartbeat timeout")),
        "eviction must come from the heartbeat detector: {lines:?}"
    );
    assert!(report.rows.iter().any(|r| r.migrations >= 1), "{report:?}");
    for w in workers {
        let _ = w.join(); // both exit on the Shutdown broadcast
    }
    assert_bit_exact(&coordinator, &text);
}

#[test]
fn all_workers_dead_is_workers_lost_with_exit_code_4() {
    let _guard = fault::test_lock();
    fault::install(fault::parse_faults("worker/zz-dist-dead-w0:panic@turn=1").unwrap());
    let text = manifest(&[("dd-a", 41)]);
    let mut coordinator =
        Coordinator::new(manifest_job_payloads(&text).unwrap(), DistOptions::default());
    let workers = spawn_workers(&mut coordinator, &["zz-dist-dead-w0"], 2);
    let report = coordinator.run(|_| {});
    assert_eq!(report.outcome(), DistOutcome::WorkersLost, "{report:?}");
    assert_eq!(report.outcome().exit_code(), 4);
    assert_eq!(report.rows[0].status, DistJobStatus::Unfinished);
    for w in workers {
        let _ = w.join(); // died on the injected panic
    }
}

#[test]
fn dropped_and_duplicated_frames_are_absorbed() {
    let _guard = fault::test_lock();
    // Deterministic loss on the worker's link, spread across the early
    // conversation: the first send is the worker's Hello (the coordinator
    // only speaks after hearing it), so drop@1 exercises the
    // Hello-retransmission path; the later drop/dup land on whatever the
    // protocol is saying at those hits — every message must be either
    // loss-tolerant or retransmitted-until-acked.
    fault::install(
        fault::parse_faults(
            "transport_send/zz-dist-lossy-w0:drop@1,\
             transport_recv/zz-dist-lossy-w0:dup@2,\
             transport_send/zz-dist-lossy-w0:drop@7",
        )
        .unwrap(),
    );
    let text = manifest(&[("dl-a", 51)]);
    let mut coordinator = Coordinator::new(
        manifest_job_payloads(&text).unwrap(),
        DistOptions {
            heartbeat_timeout: Duration::from_secs(30),
            assign_resend_rounds: 4,
            ..DistOptions::default()
        },
    );
    let workers = spawn_workers(&mut coordinator, &["zz-dist-lossy-w0"], 4);
    let report = coordinator.run(|_| {});
    assert_eq!(report.outcome(), DistOutcome::AllDone, "{report:?}");
    for w in workers {
        let _ = w.join();
    }
    assert_bit_exact(&coordinator, &text);
}

#[test]
fn ci_chaos_profile_parses() {
    // The exact profile the CI chaos matrix cell arms via MSGSN_FAULTS —
    // a parse regression here would make that cell fail at startup.
    let specs =
        fault::parse_faults("transport_recv:drop@turn=32,worker:panic@2").unwrap();
    assert_eq!(specs.len(), 2);
}
