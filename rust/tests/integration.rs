//! End-to-end integration tests: full reconstructions across layers.

use msgsn::config::{Algorithm, Driver, RunConfig};
use msgsn::engine::{make_algorithm, make_findwinners, run, run_multi_signal, run_single_signal};
use msgsn::mesh::{benchmark_mesh, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::topology::euler_characteristic;

/// A demo-scale config (2× threshold ⇒ ~1/4 the paper-size network).
fn demo_cfg(shape: BenchmarkShape, max_signals: u64) -> RunConfig {
    let mut cfg = RunConfig::preset(shape);
    cfg.soam.insertion_threshold *= 2.0;
    cfg.gwr.insertion_threshold *= 2.0;
    cfg.limits.max_signals = max_signals;
    cfg
}

#[test]
fn soam_blob_converges_to_genus_zero() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let cfg = demo_cfg(BenchmarkShape::Blob, 4_000_000);
    let sampler = SurfaceSampler::new(&mesh);
    let mut algo = make_algorithm(&cfg);
    let mut fw = make_findwinners(&cfg).unwrap();
    let mut rng = Rng::seed_from(42);
    let report = run_multi_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, &mut rng);
    assert!(report.converged, "no convergence in {} signals", report.signals);
    // At convergence the network is a closed triangulated 2-manifold of the
    // source's genus (the paper's Fig. 1 property).
    let adj = algo.net().adjacency_map();
    let chi = euler_characteristic(&adj);
    assert_eq!(chi, 2, "blob reconstruction must be a sphere (chi=2)");
    algo.net().check_invariants().unwrap();
    // Every unit's link is a closed cycle ⇒ degree ≥ 3 everywhere.
    for id in algo.net().ids() {
        assert!(algo.net().degree(id) >= 3, "unit {id} under-connected");
    }
}

#[test]
fn soam_eight_converges_to_genus_two() {
    let mesh = benchmark_mesh(BenchmarkShape::Eight, 48);
    let cfg = demo_cfg(BenchmarkShape::Eight, 8_000_000);
    let sampler = SurfaceSampler::new(&mesh);
    let mut algo = make_algorithm(&cfg);
    let mut fw = make_findwinners(&cfg).unwrap();
    let mut rng = Rng::seed_from(7);
    let report = run_multi_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, &mut rng);
    assert!(report.converged, "no convergence in {} signals", report.signals);
    let adj = algo.net().adjacency_map();
    let chi = euler_characteristic(&adj);
    assert_eq!(chi, -2, "double torus reconstruction must have chi=-2 (genus 2)");
}

#[test]
fn single_signal_converges_too() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let cfg = demo_cfg(BenchmarkShape::Blob, 4_000_000);
    let sampler = SurfaceSampler::new(&mesh);
    let mut algo = make_algorithm(&cfg);
    let mut fw = make_findwinners(&cfg).unwrap();
    let mut rng = Rng::seed_from(42);
    let report = run_single_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, &mut rng);
    assert!(report.converged);
    assert_eq!(report.discarded, 0);
    assert_eq!(report.signals, report.iterations);
}

#[test]
fn multi_needs_fewer_effective_signals_than_single() {
    // The paper's central behavioral claim (§3.2): "the Multi-signal variant
    // always needs a substantially lower number of input signals than the
    // Single-signal one to converge", counting effective (non-discarded)
    // signals.
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let cfg = demo_cfg(BenchmarkShape::Blob, 6_000_000);
    let mut r1 = Rng::seed_from(3);
    let mut r2 = Rng::seed_from(3);
    let single = run(&mesh, Driver::Single, &cfg, &mut r1).unwrap();
    let multi = run(&mesh, Driver::Multi, &cfg, &mut r2).unwrap();
    assert!(single.converged && multi.converged);
    assert!(
        multi.effective_signals() < single.signals,
        "multi effective {} !< single {}",
        multi.effective_signals(),
        single.signals
    );
}

#[test]
fn indexed_converges_with_low_fallback_rate() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    let cfg = demo_cfg(BenchmarkShape::Blob, 4_000_000);
    let mut rng = Rng::seed_from(42);
    let report = run(&mesh, Driver::Indexed, &cfg, &mut rng).unwrap();
    assert!(report.converged);
    assert!(report.units > 30);
}

#[test]
fn gwr_reaches_target_quantization_error() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
    let mut cfg = demo_cfg(BenchmarkShape::Blob, 1_000_000);
    cfg.algorithm = Algorithm::Gwr;
    // Equilibrium qe ≈ (spacing/2)²; threshold 0.1 ⇒ qe ≈ 2e-3 < target.
    cfg.gwr.insertion_threshold = 0.1;
    cfg.gwr.target_qe = 4e-3;
    cfg.limits.check_interval = 500;
    let mut rng = Rng::seed_from(1);
    let report = run(&mesh, Driver::Single, &cfg, &mut rng).unwrap();
    assert!(report.converged, "GWR did not reach target qe: {}", report.qe);
    assert!(report.qe < 4e-3);
}

#[test]
fn gng_grows_and_reports() {
    let mesh = benchmark_mesh(BenchmarkShape::Eight, 24);
    let mut cfg = demo_cfg(BenchmarkShape::Eight, 100_000);
    cfg.algorithm = Algorithm::Gng;
    let mut rng = Rng::seed_from(2);
    let report = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
    assert!(report.units > 100, "{} units", report.units);
    assert_eq!(report.algorithm, "gng");
}

#[test]
fn mesh_generation_all_genera() {
    // The four benchmark proxies must reproduce the paper meshes' genus
    // exactly (DESIGN.md §3's substitution justification).
    for shape in BenchmarkShape::ALL {
        // Reduced resolutions keep this test fast but must still resolve
        // every feature.
        let res = match shape {
            BenchmarkShape::Blob => 32,
            BenchmarkShape::Eight => 48,
            BenchmarkShape::Hand => 96,
            BenchmarkShape::Heptoroid => 160,
        };
        let mesh = benchmark_mesh(shape, res);
        let s = mesh.stats();
        assert!(s.watertight, "{} not watertight", shape.name());
        assert_eq!(s.components, 1, "{} fragmented", shape.name());
        assert_eq!(
            s.genus,
            Some(shape.expected_genus()),
            "{} genus mismatch: {s:?}",
            shape.name()
        );
    }
}

#[test]
fn reports_are_internally_consistent() {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 24);
    let mut cfg = demo_cfg(BenchmarkShape::Blob, 50_000);
    cfg.limits.trace = true;
    let mut rng = Rng::seed_from(5);
    let r = run(&mesh, Driver::Multi, &cfg, &mut rng).unwrap();
    assert!(r.discarded <= r.signals);
    assert!(r.phase.total() <= r.total + std::time::Duration::from_millis(50));
    assert!(!r.trace.is_empty(), "trace requested but empty");
    let last = r.trace.last().unwrap();
    assert_eq!(last.units, r.units);
}

#[test]
fn lfs_profiles_match_paper_characterization() {
    // Paper §3.1: Bunny "non-negligible variations"; Eight "relatively
    // constant LFS almost everywhere"; Hand "widely variable … considerably
    // low" in places; Heptoroid "low and variable". Our proxies must show
    // the same ordering on both axes (absolute LFS and variation).
    use msgsn::mesh::estimate_lfs;
    use msgsn::rng::Rng;
    let mut stats = std::collections::HashMap::new();
    for shape in BenchmarkShape::ALL {
        let mesh = benchmark_mesh(shape, 0);
        let mut rng = Rng::seed_from(0xFEA7);
        stats.insert(shape.name(), estimate_lfs(&mesh, 800, &mut rng));
    }
    let (blob, eight) = (stats["blob"], stats["eight"]);
    let (hand, hepta) = (stats["hand"], stats["heptoroid"]);
    // Eight: the most constant profile.
    assert!(eight.cv < blob.cv && eight.cv < hand.cv, "{eight:?}");
    // Hand: the widest variation, with very low regions.
    assert!(hand.cv > blob.cv, "{hand:?} vs {blob:?}");
    assert!(hand.p05 < eight.p05, "{hand:?}");
    // Heptoroid: the lowest absolute feature size.
    assert!(
        hepta.median < blob.median.min(eight.median).min(hand.median),
        "{hepta:?}"
    );
}
