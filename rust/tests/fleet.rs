//! Fleet integration tests — the PR 5 acceptance points that live at the
//! subsystem boundary:
//!
//! - **`fleet_determinism`**: a fleet of N jobs multiplexed over ONE shared
//!   worker pool must produce networks bit-identical to N solo runs of the
//!   same specs through the classic `run_convergence` path. Jobs share
//!   only compute (the pool), never state — any leak (a shared RNG draw, a
//!   pool generation bleeding across jobs, a region grid reused between
//!   networks) breaks the bit comparison.
//! - **checkpointed fleets**: interrupting a fleet mid-flight (checkpoint
//!   all jobs, drop the fleet, rebuild from the manifest, resume) finishes
//!   bit-identical to the uninterrupted fleet.
//!
//! The CI parity matrix re-runs this suite under the `MSGSN_TEST_*` knob
//! combinations (same contract as `rust/tests/executor_parity.rs`).

use msgsn::config::{Algorithm, Driver, RunConfig};
use msgsn::engine::{make_algorithm, make_findwinners, run_convergence};
use msgsn::fleet::{Fleet, FleetOptions, JobSpec};
use msgsn::mesh::{BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::som::Network;

/// Bitwise network equality (same contract as executor_parity's helper).
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

/// Extra knob combination injected by the CI matrix (same env contract as
/// executor_parity).
fn env_combo() -> Option<(usize, usize, usize)> {
    let knob = |name: &str| std::env::var(name).ok()?.parse().ok();
    let upd = knob("MSGSN_TEST_UPDATE_THREADS");
    let find = knob("MSGSN_TEST_FIND_THREADS");
    let regions = knob("MSGSN_TEST_REGIONS");
    if upd.is_none() && find.is_none() && regions.is_none() {
        return None;
    }
    Some((upd.unwrap_or(1), find.unwrap_or(1), regions.unwrap_or(1)))
}

fn spec(
    name: &str,
    shape: BenchmarkShape,
    algorithm: Algorithm,
    driver: Driver,
    seed: u64,
    knobs: (usize, usize, usize),
) -> JobSpec {
    let mut cfg = RunConfig::preset(shape);
    cfg.algorithm = algorithm;
    cfg.driver = driver;
    cfg.seed = seed;
    cfg.soam.insertion_threshold = 0.16;
    cfg.gwr.insertion_threshold = 0.12;
    cfg.gng.lambda = 60;
    cfg.limits.max_signals = 15_000;
    (cfg.update_threads, cfg.find_threads, cfg.regions) = knobs;
    JobSpec::from_config(name, cfg)
}

/// Solo reference: the classic blocking path (`run_convergence` with its
/// own pool wiring), keeping the algorithm so its network can be compared.
fn solo_network(spec: &JobSpec) -> (Network, u64, u64) {
    let mesh = spec.build_mesh().unwrap();
    let sampler = SurfaceSampler::new(&mesh);
    let mut algo = make_algorithm(&spec.cfg);
    let mut fw = make_findwinners(&spec.cfg).unwrap();
    let mut rng = Rng::seed_from(spec.cfg.seed);
    let r = run_convergence(algo.as_mut(), &sampler, fw.as_mut(), &spec.cfg, &mut rng);
    (algo.net().clone(), r.signals, r.discarded)
}

/// Acceptance: fleet-of-N ≡ N solo runs, bit for bit — across algorithms,
/// drivers and the knob matrix, with genuinely interleaved scheduling.
#[test]
fn fleet_determinism() {
    let mut combos = vec![(3usize, 2usize, 27usize)];
    combos.extend(env_combo());
    for knobs in combos {
        let specs = vec![
            spec("soam-par", BenchmarkShape::Blob, Algorithm::Soam, Driver::Parallel, 7, knobs),
            spec("gng-multi", BenchmarkShape::Eight, Algorithm::Gng, Driver::Multi, 9, knobs),
            spec("gwr-par", BenchmarkShape::Blob, Algorithm::Gwr, Driver::Parallel, 11, knobs),
        ];
        let mut fleet = Fleet::new(specs.clone()).unwrap();
        let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
        assert_eq!(report.jobs.len(), 3);

        for (k, spec) in specs.iter().enumerate() {
            let (net, signals, discarded) = solo_network(spec);
            let label = format!(
                "job {} knobs {:?}: fleet vs solo",
                spec.name,
                (spec.cfg.update_threads, spec.cfg.find_threads, spec.cfg.regions)
            );
            assert_eq!(report.jobs[k].1.signals, signals, "{label}");
            assert_eq!(report.jobs[k].1.discarded, discarded, "{label}");
            assert_networks_identical(&net, fleet.jobs()[k].session().algo().net(), &label);
        }
    }
}

/// The pipelined driver rides the fleet through the session's synchronous
/// prefetch equivalent — a fleet pipelined job must equal the threaded solo
/// driver bitwise.
#[test]
fn fleet_pipelined_job_matches_threaded_driver() {
    let knobs = env_combo().unwrap_or((2, 1, 8));
    let job = spec(
        "pipe",
        BenchmarkShape::Blob,
        Algorithm::Soam,
        Driver::Pipelined,
        13,
        knobs,
    );
    let (net, signals, discarded) = solo_network(&job);
    let mut fleet = Fleet::new(vec![job]).unwrap();
    let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
    assert_eq!(report.jobs[0].1.signals, signals);
    assert_eq!(report.jobs[0].1.discarded, discarded);
    assert_networks_identical(
        &net,
        fleet.jobs()[0].session().algo().net(),
        "fleet pipelined vs threaded solo",
    );
}

/// Interrupting a whole fleet (checkpoint every turn, drop, rebuild from
/// the same specs, resume) must finish bit-identical to the uninterrupted
/// fleet.
#[test]
fn fleet_checkpoint_resume_matches_uninterrupted() {
    let dir = std::env::temp_dir().join("msgsn_fleet_resume_test");
    std::fs::remove_dir_all(&dir).ok();
    let mk_specs = || {
        vec![
            spec("a", BenchmarkShape::Blob, Algorithm::Soam, Driver::Multi, 3, (1, 1, 1)),
            spec("b", BenchmarkShape::Eight, Algorithm::Gng, Driver::Multi, 5, (1, 1, 8)),
        ]
    };

    // Uninterrupted.
    let mut fleet = Fleet::new(mk_specs()).unwrap();
    let a = fleet.run(&FleetOptions::default(), |_| {}).unwrap();

    // "Interrupted": run the same specs under a LOW signal cap with
    // checkpointing on — the fleet stops mid-flight at ~4k signals and its
    // final checkpoints capture that state. Resuming those checkpoints
    // under the real caps must continue bit-identically (termination is
    // recomputed against the restored limits, so raising the budget
    // resumes the run).
    let opts = FleetOptions {
        stride: 4,
        checkpoint_every: 1,
        checkpoint_dir: Some(dir.clone()),
    };
    let mut capped_specs = mk_specs();
    for s in &mut capped_specs {
        s.cfg.limits.max_signals = 4_000;
    }
    let mut capped = Fleet::new(capped_specs).unwrap();
    capped.run(&opts, |_| {}).unwrap();
    assert!(dir.join("a.msgsnap").exists() && dir.join("b.msgsnap").exists());

    // Resume under the REAL caps: jobs continue from ~4k signals.
    let mut resumed = Fleet::new(mk_specs()).unwrap();
    let names = resumed.resume_from(&dir).unwrap();
    assert_eq!(names.len(), 2);
    let b = resumed.run(&FleetOptions::default(), |_| {}).unwrap();

    for k in 0..2 {
        let label = format!("job {k}: resumed fleet vs uninterrupted");
        assert_eq!(a.jobs[k].1.signals, b.jobs[k].1.signals, "{label}");
        assert_eq!(a.jobs[k].1.discarded, b.jobs[k].1.discarded, "{label}");
        assert_eq!(a.jobs[k].1.qe.to_bits(), b.jobs[k].1.qe.to_bits(), "{label}");
        assert_networks_identical(
            fleet.jobs()[k].session().algo().net(),
            resumed.jobs()[k].session().algo().net(),
            &label,
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
