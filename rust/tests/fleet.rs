//! Fleet integration tests — the PR 5 determinism acceptance points plus
//! the PR 7 crash-safety acceptance points, all at the subsystem boundary:
//!
//! - **`fleet_determinism`**: a fleet of N jobs multiplexed over ONE shared
//!   worker pool must produce networks bit-identical to N solo runs of the
//!   same specs through the classic `run_convergence` path. Jobs share
//!   only compute (the pool), never state — any leak (a shared RNG draw, a
//!   pool generation bleeding across jobs, a region grid reused between
//!   networks) breaks the bit comparison.
//! - **checkpointed fleets**: interrupting a fleet mid-flight (checkpoint
//!   all jobs, drop the fleet, rebuild from the manifest, resume) finishes
//!   bit-identical to the uninterrupted fleet.
//! - **torn writes**: a checkpoint write cut at EVERY byte offset leaves a
//!   fleet that resumes from the retained previous generation, bit for
//!   bit (`torn_checkpoint_write_recovers_at_every_byte_offset`).
//! - **poison jobs**: a job panicking at an injected turn is retried,
//!   quarantined after its budget, and the surviving jobs finish
//!   bit-identical to a fleet that never contained it — with the report
//!   and exit code saying partial failure.
//!
//! The CI parity matrix re-runs this suite under the `MSGSN_TEST_*` knob
//! combinations, and one matrix cell re-runs it single-threaded under the
//! `MSGSN_FAULTS` torn-write + job-panic profile (every recovery path is
//! *transparent* — bit-exact restore/retry means the same assertions must
//! hold with faults armed).

use std::path::PathBuf;

use msgsn::config::{Algorithm, Driver, RunConfig};
use msgsn::engine::{make_algorithm, make_findwinners, run_convergence, ConvergenceSession};
use msgsn::fleet::snapshot::{prev_path, restore_session, snapshot_session, write_durable};
use msgsn::fleet::{Fleet, FleetOptions, FleetOutcome, JobSpec, JobStatus, RestoreSource};
use msgsn::mesh::{BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::runtime::fault;
use msgsn::som::Network;

/// Bitwise network equality (same contract as executor_parity's helper).
fn assert_networks_identical(a: &Network, b: &Network, label: &str) {
    assert_eq!(a.capacity(), b.capacity(), "{label}: slab capacity");
    assert_eq!(a.len(), b.len(), "{label}: live units");
    assert_eq!(a.edge_count(), b.edge_count(), "{label}: edges");
    for id in 0..a.capacity() as u32 {
        assert_eq!(a.is_alive(id), b.is_alive(id), "{label}: aliveness of {id}");
        if !a.is_alive(id) {
            continue;
        }
        let (ua, ub) = (a.unit(id), b.unit(id));
        for (va, vb, what) in [
            (ua.pos.x, ub.pos.x, "pos.x"),
            (ua.pos.y, ub.pos.y, "pos.y"),
            (ua.pos.z, ub.pos.z, "pos.z"),
            (ua.firing, ub.firing, "firing"),
            (ua.error, ub.error, "error"),
            (ua.threshold, ub.threshold, "threshold"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{label}: unit {id} {what}");
        }
        let ea: Vec<(u32, u32)> =
            a.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        let eb: Vec<(u32, u32)> =
            b.edges_of(id).iter().map(|e| (e.to, e.age.to_bits())).collect();
        assert_eq!(ea, eb, "{label}: edges of {id}");
    }
}

/// Extra knob combination injected by the CI matrix (same env contract as
/// executor_parity).
fn env_combo() -> Option<(usize, usize, usize)> {
    let knob = |name: &str| std::env::var(name).ok()?.parse().ok();
    let upd = knob("MSGSN_TEST_UPDATE_THREADS");
    let find = knob("MSGSN_TEST_FIND_THREADS");
    let regions = knob("MSGSN_TEST_REGIONS");
    if upd.is_none() && find.is_none() && regions.is_none() {
        return None;
    }
    Some((upd.unwrap_or(1), find.unwrap_or(1), regions.unwrap_or(1)))
}

fn spec(
    name: &str,
    shape: BenchmarkShape,
    algorithm: Algorithm,
    driver: Driver,
    seed: u64,
    knobs: (usize, usize, usize),
) -> JobSpec {
    let mut cfg = RunConfig::preset(shape);
    cfg.algorithm = algorithm;
    cfg.driver = driver;
    cfg.seed = seed;
    cfg.soam.insertion_threshold = 0.16;
    cfg.gwr.insertion_threshold = 0.12;
    cfg.gng.lambda = 60;
    cfg.limits.max_signals = 15_000;
    (cfg.update_threads, cfg.find_threads, cfg.regions) = knobs;
    JobSpec::from_config(name, cfg)
}

/// A deliberately small job for the fault-injection tests: the torn-write
/// sweep restores a session per byte offset, so snapshot size and session
/// build cost both matter.
fn tiny_spec(name: &str, seed: u64) -> JobSpec {
    let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
    cfg.driver = Driver::Multi;
    cfg.algorithm = Algorithm::Soam;
    cfg.seed = seed;
    cfg.mesh_resolution = 16;
    cfg.soam.insertion_threshold = 0.2;
    cfg.limits.max_signals = 4_000;
    JobSpec::from_config(name, cfg)
}

/// Unique per-test checkpoint dir: parallel `cargo test` processes (and
/// parallel tests within one) must never share on-disk state.
fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("msgsn_it_{}_{}", std::process::id(), name));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Solo reference: the classic blocking path (`run_convergence` with its
/// own pool wiring), keeping the algorithm so its network can be compared.
fn solo_network(spec: &JobSpec) -> (Network, u64, u64) {
    let mesh = spec.build_mesh().unwrap();
    let sampler = SurfaceSampler::new(&mesh);
    let mut algo = make_algorithm(&spec.cfg);
    let mut fw = make_findwinners(&spec.cfg).unwrap();
    let mut rng = Rng::seed_from(spec.cfg.seed);
    let r = run_convergence(algo.as_mut(), &sampler, fw.as_mut(), &spec.cfg, &mut rng);
    (algo.net().clone(), r.signals, r.discarded)
}

/// Acceptance: fleet-of-N ≡ N solo runs, bit for bit — across algorithms,
/// drivers and the knob matrix, with genuinely interleaved scheduling.
#[test]
fn fleet_determinism() {
    let mut combos = vec![(3usize, 2usize, 27usize)];
    combos.extend(env_combo());
    for knobs in combos {
        let specs = vec![
            spec("soam-par", BenchmarkShape::Blob, Algorithm::Soam, Driver::Parallel, 7, knobs),
            spec("gng-multi", BenchmarkShape::Eight, Algorithm::Gng, Driver::Multi, 9, knobs),
            spec("gwr-par", BenchmarkShape::Blob, Algorithm::Gwr, Driver::Parallel, 11, knobs),
        ];
        let mut fleet = Fleet::new(specs.clone()).unwrap();
        let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
        assert_eq!(report.rows.len(), 3);

        for (k, spec) in specs.iter().enumerate() {
            let (net, signals, discarded) = solo_network(spec);
            let label = format!(
                "job {} knobs {:?}: fleet vs solo",
                spec.name,
                (spec.cfg.update_threads, spec.cfg.find_threads, spec.cfg.regions)
            );
            let row = report.rows[k].report.as_ref().unwrap();
            assert_eq!(row.signals, signals, "{label}");
            assert_eq!(row.discarded, discarded, "{label}");
            assert_networks_identical(
                &net,
                fleet.jobs()[k].session().unwrap().algo().net(),
                &label,
            );
        }
    }
}

/// The pipelined driver rides the fleet through the session's synchronous
/// prefetch equivalent — a fleet pipelined job must equal the threaded solo
/// driver bitwise.
#[test]
fn fleet_pipelined_job_matches_threaded_driver() {
    let knobs = env_combo().unwrap_or((2, 1, 8));
    let job = spec(
        "pipe",
        BenchmarkShape::Blob,
        Algorithm::Soam,
        Driver::Pipelined,
        13,
        knobs,
    );
    let (net, signals, discarded) = solo_network(&job);
    let mut fleet = Fleet::new(vec![job]).unwrap();
    let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
    let row = report.rows[0].report.as_ref().unwrap();
    assert_eq!(row.signals, signals);
    assert_eq!(row.discarded, discarded);
    assert_networks_identical(
        &net,
        fleet.jobs()[0].session().unwrap().algo().net(),
        "fleet pipelined vs threaded solo",
    );
}

/// Interrupting a whole fleet (checkpoint every turn, drop, rebuild from
/// the same specs, resume) must finish bit-identical to the uninterrupted
/// fleet.
#[test]
fn fleet_checkpoint_resume_matches_uninterrupted() {
    let dir = scratch_dir("fleet_resume");
    let mk_specs = || {
        vec![
            spec("a", BenchmarkShape::Blob, Algorithm::Soam, Driver::Multi, 3, (1, 1, 1)),
            spec("b", BenchmarkShape::Eight, Algorithm::Gng, Driver::Multi, 5, (1, 1, 8)),
        ]
    };

    // Uninterrupted.
    let mut fleet = Fleet::new(mk_specs()).unwrap();
    let a = fleet.run(&FleetOptions::default(), |_| {}).unwrap();

    // "Interrupted": run the same specs under a LOW signal cap with
    // checkpointing on — the fleet stops mid-flight at ~4k signals and its
    // final checkpoints capture that state. Resuming those checkpoints
    // under the real caps must continue bit-identically (termination is
    // recomputed against the restored limits, so raising the budget
    // resumes the run).
    let opts = FleetOptions {
        stride: 4,
        checkpoint_every: 1,
        checkpoint_dir: Some(dir.clone()),
        ..FleetOptions::default()
    };
    let mut capped_specs = mk_specs();
    for s in &mut capped_specs {
        s.cfg.limits.max_signals = 4_000;
    }
    let mut capped = Fleet::new(capped_specs).unwrap();
    capped.run(&opts, |_| {}).unwrap();
    assert!(dir.join("a.msgsnap").exists() && dir.join("b.msgsnap").exists());

    // Resume under the REAL caps: jobs continue from ~4k signals.
    let mut resumed = Fleet::new(mk_specs()).unwrap();
    let outcomes = resumed.resume_from(&dir).unwrap();
    assert_eq!(outcomes.len(), 2);
    let b = resumed.run(&FleetOptions::default(), |_| {}).unwrap();

    for k in 0..2 {
        let label = format!("job {k}: resumed fleet vs uninterrupted");
        let (ra, rb) =
            (a.rows[k].report.as_ref().unwrap(), b.rows[k].report.as_ref().unwrap());
        assert_eq!(ra.signals, rb.signals, "{label}");
        assert_eq!(ra.discarded, rb.discarded, "{label}");
        assert_eq!(ra.qe.to_bits(), rb.qe.to_bits(), "{label}");
        assert_networks_identical(
            fleet.jobs()[k].session().unwrap().algo().net(),
            resumed.jobs()[k].session().unwrap().algo().net(),
            &label,
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance (crash-safety, part a): tear the checkpoint write at EVERY
/// byte offset — the fleet must resume from the retained previous
/// generation, restoring its exact bytes, and promote it so the next
/// rotation cannot clobber the only good state. At sampled offsets the
/// recovered fleet additionally runs to completion and must be
/// bit-identical to a clean resume of the same generation.
#[test]
fn torn_checkpoint_write_recovers_at_every_byte_offset() {
    let _guard = fault::test_lock();
    let dir = scratch_dir("torn_every_offset");
    std::fs::create_dir_all(&dir).unwrap();
    let spec = tiny_spec("tornjob", 17);
    let mesh = spec.build_mesh().unwrap();

    // Two checkpoint generations of one session: gen1 is the "last good"
    // state, gen2 the write that gets torn.
    let mut session = ConvergenceSession::new(&spec.cfg, &mesh, None).unwrap();
    session.step(2);
    let gen1 = snapshot_session(&session);
    session.step(2);
    let gen2 = snapshot_session(&session);

    let latest = dir.join("tornjob.msgsnap");
    let prev = prev_path(&latest);
    let stem = latest.file_stem().unwrap().to_str().unwrap().to_string();
    let arm_torn_write = |cut: usize| {
        fault::install(
            fault::parse_faults(&format!("checkpoint_write/{stem}:truncate={cut}@1"))
                .unwrap(),
        );
    };
    let lay_out_torn_generation = |cut: usize| {
        std::fs::remove_file(&latest).ok();
        std::fs::remove_file(&prev).ok();
        write_durable(&latest, &gen1).unwrap();
        arm_torn_write(cut);
        write_durable(&latest, &gen2).unwrap();
        fault::clear();
        assert_eq!(std::fs::read(&latest).unwrap(), &gen2[..cut], "torn at {cut}");
        assert_eq!(std::fs::read(&prev).unwrap(), gen1, "prev retained at {cut}");
    };

    // Every byte offset: the fleet is reused (resume_from rebuilds each
    // job's session from disk every call), so one sweep iteration costs a
    // restore, not a full fleet build.
    let mut fleet = Fleet::new(vec![spec.clone()]).unwrap();
    for cut in 0..gen2.len() {
        lay_out_torn_generation(cut);
        let outcomes = fleet.resume_from(&dir).unwrap();
        assert_eq!(outcomes.len(), 1, "cut {cut}");
        assert_eq!(outcomes[0].source, RestoreSource::Previous, "cut {cut}");
        assert_eq!(
            snapshot_session(fleet.jobs()[0].session().unwrap()),
            gen1,
            "cut {cut}: restored state must be the last good generation, bit for bit"
        );
        // Promotion: the good generation now holds the latest name, so a
        // subsequent rotation cannot shift the torn file over it.
        assert_eq!(std::fs::read(&latest).unwrap(), gen1, "cut {cut}: promoted");
        assert!(!prev.exists(), "cut {cut}: prev consumed by promotion");
    }

    // Sampled offsets: run the recovered fleet to the end — recovery must
    // be invisible in the final bits.
    let reference = {
        let mut s = ConvergenceSession::new(&spec.cfg, &mesh, None).unwrap();
        restore_session(&mut s, &gen1).unwrap();
        let r = s.run_to_end();
        (s, r)
    };
    for cut in [0usize, 9, gen2.len() / 2, gen2.len() - 3] {
        lay_out_torn_generation(cut);
        let mut recovered = Fleet::new(vec![spec.clone()]).unwrap();
        recovered.resume_from(&dir).unwrap();
        let report = recovered.run(&FleetOptions::default(), |_| {}).unwrap();
        let row = report.rows[0].report.as_ref().unwrap();
        assert_eq!(row.signals, reference.1.signals, "cut {cut}");
        assert_eq!(row.qe.to_bits(), reference.1.qe.to_bits(), "cut {cut}");
        assert_networks_identical(
            reference.0.algo().net(),
            recovered.jobs()[0].session().unwrap().algo().net(),
            &format!("cut {cut}: recovered fleet vs clean resume"),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance (crash-safety, part b): a poison job panicking at an
/// injected turn on every attempt is quarantined after its retry budget,
/// while the remaining jobs finish bit-identically to a fleet that never
/// contained it — and the report + exit code say partial failure.
#[test]
fn poison_job_is_quarantined_and_isolated() {
    let _guard = fault::test_lock();
    let healthy = || {
        vec![
            spec("iso-a", BenchmarkShape::Blob, Algorithm::Soam, Driver::Multi, 7, (1, 1, 1)),
            spec("iso-b", BenchmarkShape::Eight, Algorithm::Gng, Driver::Multi, 9, (1, 1, 8)),
        ]
    };

    // Clean reference: the fleet without the poison job.
    fault::clear();
    let mut clean = Fleet::new(healthy()).unwrap();
    let clean_report = clean.run(&FleetOptions::default(), |_| {}).unwrap();

    // Poisoned fleet: the same two jobs plus one that panics at turn ≥ 9
    // on its first run AND both retries (three spec copies; the default
    // budget is max_retries = 2).
    let mut specs = healthy();
    specs.push(spec("poison", BenchmarkShape::Blob, Algorithm::Gwr, Driver::Multi, 11, (1, 1, 1)));
    fault::install(
        fault::parse_faults(
            "job/poison:panic@turn=9,job/poison:panic@turn=9,job/poison:panic@turn=9",
        )
        .unwrap(),
    );
    let mut fleet = Fleet::new(specs).unwrap();
    let mut events = Vec::new();
    let report = fleet.run(&FleetOptions::default(), |l| events.push(l.to_string())).unwrap();

    let poison = &report.rows[2];
    assert_eq!(poison.name, "poison");
    assert_eq!(poison.status, JobStatus::Quarantined);
    assert_eq!(poison.attempts, 3, "first run + two retries");
    assert!(poison.error.as_deref().unwrap().contains("injected fault"), "{:?}", poison.error);
    assert!(poison.report.is_none());
    assert_eq!(report.outcome(), FleetOutcome::PartialFailure);
    assert_eq!(report.outcome().exit_code(), 2);
    assert!(events.iter().any(|l| l.contains("QUARANTINED")), "{events:?}");
    let rendered = report.to_table().render();
    assert!(rendered.contains("quarantined"), "{rendered}");

    for k in 0..2 {
        let label = format!("job {}: poisoned fleet vs clean fleet", report.rows[k].name);
        assert_eq!(report.rows[k].status, JobStatus::Done, "{label}");
        let (ra, rb) = (
            clean_report.rows[k].report.as_ref().unwrap(),
            report.rows[k].report.as_ref().unwrap(),
        );
        assert_eq!(ra.signals, rb.signals, "{label}");
        assert_eq!(ra.qe.to_bits(), rb.qe.to_bits(), "{label}");
        assert_networks_identical(
            clean.jobs()[k].session().unwrap().algo().net(),
            fleet.jobs()[k].session().unwrap().algo().net(),
            &label,
        );
    }
}

/// Every job quarantined (here via the per-job `retries: 0` manifest
/// override — first failure is final) is total failure: exit code 3, and
/// the report renders placeholder columns instead of garbage.
#[test]
fn all_jobs_quarantined_is_total_failure() {
    let _guard = fault::test_lock();
    let mut doomed = tiny_spec("doomed", 3);
    doomed.retries = Some(0);
    fault::install(fault::parse_faults("job/doomed:panic@turn=2").unwrap());
    let mut fleet = Fleet::new(vec![doomed]).unwrap();
    let report = fleet.run(&FleetOptions::default(), |_| {}).unwrap();
    assert_eq!(report.rows[0].status, JobStatus::Quarantined);
    assert_eq!(report.rows[0].attempts, 1, "retries: 0 quarantines on the first failure");
    assert!(report.rows[0].report.is_none());
    assert_eq!(report.outcome(), FleetOutcome::AllFailed);
    assert_eq!(report.outcome().exit_code(), 3);
    let rendered = report.to_table().render();
    assert!(rendered.contains('-'), "{rendered}");
}

/// A crash mid-run retries from the latest checkpoint and finishes
/// bit-identical to a fleet that never crashed — recovery is invisible in
/// the final state, which is the property that makes the CI fault profile
/// sound.
#[test]
fn retry_restores_from_checkpoint_bit_exactly() {
    let _guard = fault::test_lock();
    let dir = scratch_dir("retry_ckpt");
    let flaky = tiny_spec("flaky", 29);

    // Clean reference run (no faults, no checkpoints).
    fault::clear();
    let mut clean = Fleet::new(vec![flaky.clone()]).unwrap();
    let clean_report = clean.run(&FleetOptions::default(), |_| {}).unwrap();

    // Crash at turn ≥ 8 with checkpoints every 2 turns: the retry restores
    // the iteration-8 checkpoint and continues.
    fault::install(fault::parse_faults("job/flaky:panic@turn=8").unwrap());
    let opts = FleetOptions {
        stride: 2,
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.clone()),
        ..FleetOptions::default()
    };
    let mut fleet = Fleet::new(vec![flaky]).unwrap();
    let mut events = Vec::new();
    let report = fleet.run(&opts, |l| events.push(l.to_string())).unwrap();
    assert_eq!(report.rows[0].status, JobStatus::Done);
    assert_eq!(report.rows[0].attempts, 1);
    assert_eq!(report.outcome().exit_code(), 0, "a recovered job is a success");
    assert!(
        events.iter().any(|l| l.contains("retrying from latest checkpoint")),
        "{events:?}"
    );
    let (ra, rb) = (
        clean_report.rows[0].report.as_ref().unwrap(),
        report.rows[0].report.as_ref().unwrap(),
    );
    assert_eq!(ra.signals, rb.signals);
    assert_eq!(ra.qe.to_bits(), rb.qe.to_bits());
    assert_networks_identical(
        clean.jobs()[0].session().unwrap().algo().net(),
        fleet.jobs()[0].session().unwrap().algo().net(),
        "retried fleet vs never-crashed fleet",
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Dynamic admission (the serve daemon's substrate): jobs added and
/// removed mid-run through `add_job`/`remove_job` — previously only
/// exercised via the dist worker — leave every *surviving* job
/// bit-identical to a static fleet that ran it start-to-finish. The
/// round-robin scheduler steps each live job by its own turn counter, so
/// membership churn reshuffles interleaving, never per-job state.
#[test]
fn dynamic_admission_is_bit_identical_to_static_fleet() {
    // Static references, run solo so membership never differs.
    let reference = |name: &str, seed: u64| {
        let mut fleet = Fleet::new(vec![tiny_spec(name, seed)]).unwrap();
        fleet.run(&FleetOptions::default(), |_| {}).unwrap();
        fleet
    };
    let ref_a = reference("dyn-a", 31);
    let ref_c = reference("dyn-c", 33);

    // Dynamic fleet: starts [a, b]; c arrives mid-run; b is cancelled
    // mid-run; the survivors drain to completion.
    let mut fleet =
        Fleet::new(vec![tiny_spec("dyn-a", 31), tiny_spec("dyn-b", 32)]).unwrap();
    let opts = FleetOptions::default();
    let mut progress = |_: &str| {};
    let mut round = 0u64;
    loop {
        // Mutations land between rounds — the same batch-boundary
        // consistency point the serve daemon handles requests at.
        if round == 2 {
            fleet.add_job(tiny_spec("dyn-c", 33)).unwrap();
        }
        if round == 4 {
            assert!(fleet.remove_job("dyn-b"), "dyn-b was admitted at start");
        }
        let live = fleet.step_round(&opts, round, None, &mut progress);
        round += 1;
        if live == 0 && round > 4 {
            break;
        }
    }

    let names: Vec<&str> = fleet.jobs().iter().map(|j| j.spec().name.as_str()).collect();
    assert_eq!(names, ["dyn-a", "dyn-c"], "cancelled job lingered");
    for (reference, name) in [(&ref_a, "dyn-a"), (&ref_c, "dyn-c")] {
        let survivor = fleet.jobs().iter().find(|j| j.spec().name == name).unwrap();
        assert_eq!(survivor.status(), JobStatus::Done);
        assert_networks_identical(
            reference.jobs()[0].session().unwrap().algo().net(),
            survivor.session().unwrap().algo().net(),
            &format!("{name}: dynamic vs static fleet"),
        );
    }
}

/// The CI fault-matrix profile must parse — a typo in the workflow's
/// `MSGSN_FAULTS` value would otherwise panic at the first fault-point
/// evaluation of every test in the cell.
#[test]
fn ci_fault_profile_parses() {
    let specs =
        fault::parse_faults("checkpoint_write:truncate=24@2,job:panic@turn=48").unwrap();
    assert_eq!(specs.len(), 2);
}
