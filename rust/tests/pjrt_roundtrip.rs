//! PJRT round-trip: the AOT artifacts (python/jax/pallas → HLO text) loaded
//! and executed from rust must agree EXACTLY with the in-rust reference
//! implementations. This is the cross-layer seam of the whole system.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::path::{Path, PathBuf};

use msgsn::findwinners::{BatchRust, FindWinners, Scalar};
use msgsn::som::Winners;
use msgsn::geometry::Vec3;
use msgsn::rng::Rng;
use msgsn::runtime::{PjrtFindWinners, Registry, PAD_VALUE};
use msgsn::som::Network;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn random_network(n: usize, seed: u64) -> Network {
    let mut rng = Rng::seed_from(seed);
    let mut net = Network::new();
    for _ in 0..n {
        net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1);
    }
    net
}

fn random_signals(m: usize, seed: u64) -> Vec<Vec3> {
    let mut rng = Rng::seed_from(seed);
    (0..m).map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32())).collect()
}


/// Winner-index equality with distance tolerance (XLA FMA contraction can
/// shift raw distance bits by ~1 ulp; indices must still agree — a flip
/// would need two units equidistant to within 1 ulp).
fn assert_winners_match(got: &[Option<Winners>], want: &[Option<Winners>]) {
    assert_eq!(got.len(), want.len());
    for (j, (g, w)) in got.iter().zip(want).enumerate() {
        match (g, w) {
            (None, None) => {}
            (Some(g), Some(w)) => {
                assert_eq!(g.w1, w.w1, "winner at {j}");
                assert_eq!(g.w2, w.w2, "second at {j}");
                assert!((g.d1_sq - w.d1_sq).abs() <= 1e-6 * w.d1_sq.max(1e-3));
                assert!((g.d2_sq - w.d2_sq).abs() <= 1e-6 * w.d2_sq.max(1e-3));
            }
            _ => panic!("Some/None mismatch at {j}: {g:?} vs {w:?}"),
        }
    }
}

#[test]
fn registry_opens_and_lists_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::open(&dir, None).unwrap();
    assert!(reg.manifest().artifacts.len() >= 8);
    let b = reg.bucket_for(100, 100).unwrap();
    assert_eq!((b.m, b.n), (128, 128));
}

#[test]
fn execute_matches_reference_both_flavors() {
    let Some(dir) = artifacts_dir() else { return };
    for flavor in ["pallas", "scan"] {
        let mut reg = Registry::open(&dir, Some(flavor)).unwrap();
        let entry = reg.bucket_for(128, 128).unwrap();
        // 100 live signals / 90 live units inside a 128/128 bucket.
        let signals = random_signals(100, 1);
        let net = random_network(90, 2);
        let mut sig_buf = Vec::new();
        for s in &signals {
            sig_buf.extend_from_slice(&[s.x, s.y, s.z]);
        }
        sig_buf.resize(entry.m * 3, 0.0);
        let mut unit_buf = Vec::new();
        net.fill_positions(&mut unit_buf, PAD_VALUE);
        unit_buf.resize(entry.n * 3, PAD_VALUE);

        let (i1, i2, d1, d2) = reg.execute(&entry, &sig_buf, &unit_buf).unwrap();
        let mut scalar = Scalar::new();
        for (j, s) in signals.iter().enumerate() {
            let w = scalar.find2(&net, *s).unwrap();
            assert_eq!(i1[j] as u32, w.w1, "{flavor} winner at {j}");
            assert_eq!(i2[j] as u32, w.w2, "{flavor} second at {j}");
            // XLA's LLVM backend contracts mul+add into FMA with
            // lane-dependent grouping, so raw distance bits may differ by
            // ~1 ulp from the rust expression (DESIGN.md section 7).
            assert!((d1[j] - w.d1_sq).abs() <= 1e-6 * w.d1_sq.max(1e-3),
                "{flavor} d1 at {j}: {} vs {}", d1[j], w.d1_sq);
            assert!((d2[j] - w.d2_sq).abs() <= 1e-6 * w.d2_sq.max(1e-3),
                "{flavor} d2 at {j}: {} vs {}", d2[j], w.d2_sq);
        }
    }
}

#[test]
fn pjrt_findwinners_matches_batchrust_with_dead_slots() {
    let Some(dir) = artifacts_dir() else { return };
    let mut net = random_network(300, 3);
    // Kill a third of the units: the slab now has PAD holes.
    let ids: Vec<u32> = net.ids().collect();
    for (k, id) in ids.iter().enumerate() {
        if k % 3 == 0 {
            net.remove(*id);
        }
    }
    let signals = random_signals(333, 4);
    let mut pjrt = PjrtFindWinners::new(Registry::open(&dir, None).unwrap());
    let mut batch = BatchRust::default();
    let mut got = Vec::new();
    let mut want = Vec::new();
    pjrt.find2_batch(&net, &signals, &mut got);
    batch.find2_batch(&net, &signals, &mut want);
    assert_winners_match(&got, &want);
}

#[test]
fn pjrt_handles_tiny_network() {
    let Some(dir) = artifacts_dir() else { return };
    let net = random_network(2, 5);
    let signals = random_signals(8, 6);
    let mut pjrt = PjrtFindWinners::new(Registry::open(&dir, None).unwrap());
    let mut got = Vec::new();
    pjrt.find2_batch(&net, &signals, &mut got);
    let mut scalar = Scalar::new();
    let want: Vec<Option<Winners>> =
        signals.iter().map(|s| scalar.find2(&net, *s)).collect();
    assert_winners_match(&got, &want);
}

#[test]
fn pjrt_one_live_unit_yields_none() {
    let Some(dir) = artifacts_dir() else { return };
    let net = random_network(1, 7);
    let signals = random_signals(4, 8);
    let mut pjrt = PjrtFindWinners::new(Registry::open(&dir, None).unwrap());
    let mut got = Vec::new();
    pjrt.find2_batch(&net, &signals, &mut got);
    assert!(got.iter().all(|w| w.is_none()));
}

#[test]
fn bucket_ladder_crossing_is_seamless() {
    let Some(dir) = artifacts_dir() else { return };
    // A network just past the 128 bucket boundary must route to 256.
    let net = random_network(130, 9);
    let signals = random_signals(130, 10);
    let mut pjrt = PjrtFindWinners::new(Registry::open(&dir, None).unwrap());
    let mut batch = BatchRust::default();
    let mut got = Vec::new();
    let mut want = Vec::new();
    pjrt.find2_batch(&net, &signals, &mut got);
    batch.find2_batch(&net, &signals, &mut want);
    assert_winners_match(&got, &want);
}

#[test]
fn warmup_precompiles() {
    let Some(dir) = artifacts_dir() else { return };
    let mut reg = Registry::open(&dir, Some("scan")).unwrap();
    let n = reg.warmup(512).unwrap();
    assert!(n >= 3, "expected at least 3 buckets <= 512, got {n}");
    assert_eq!(reg.stats.compilations as usize, n);
}
