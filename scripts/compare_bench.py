#!/usr/bin/env python3
"""Diff freshly produced BENCH_*.json files against committed baselines.

Usage: compare_bench.py --baseline DIR --fresh DIR [--threshold 0.10]

For every BENCH_*.json in the fresh directory:

- no committed counterpart                       -> skipped (new bench)
- counterpart has "status": "instrumented-not-measured"
                                                 -> skipped (placeholder:
                                                    no real numbers yet)
- both carry a "signals" workload stamp and they differ
                                                 -> skipped (different
                                                    workload scales are not
                                                    comparable)
- otherwise every timing field of every matching row is compared and the
  script fails (exit 1) when fresh > committed * (1 + threshold).

Rows are dicts inside any JSON array, matched across files by their "row"
key (driver rows) or "units" key (microbench rows). Fleet rows (the
end_to_end "fleet-concurrent"/"fleet-sequential" pair) additionally carry
a "jobs" field that becomes part of the key, so the same row name recorded
at different fleet sizes never collides — re-sizing the fleet bench shows
up as a new row (skipped) instead of a bogus diff. Dist rows additionally
carry a "transport" field ("channel", "tcp") that joins the key for the
same reason: the same fleet shape over a different transport is a new row,
never a cross-diff. Serve rows carry a "serve": true field that suffixes
the key ("<row>/jobs=N/serve"), so daemon-path measurements (protocol +
scheduling on top of the fleet) never cross-diff against batch-fleet rows
of the same name and size. Telemetry rows carry a "telemetry" field
("off", "on") that suffixes the key ("<row>/telemetry=on"), so the
instrumented and uninstrumented arms of the telemetry-overhead bench are
tracked as separate measurements and never cross-diff — a regression in
the "on" arm is reported against the previous "on" number, not against
the cheaper "off" arm. Likewise the per-ISA
find_winners rows carry an "isa" field that becomes part of the key, so a
baseline recorded on an AVX-512 host never cross-diffs against a fresh run
on an AVX2-only host — a tier the host lacks is a skipped/new row, never a
bogus regression. Timing fields are the
numeric entries whose name ends in "_s" or "_ns_per_signal". Speedups are
reported but never fail the run.
"""

import argparse
import glob
import json
import os
import sys


def rows_by_key(node, out):
    """Collect keyed row-dicts from arbitrarily nested JSON."""
    if isinstance(node, dict):
        key = None
        if "row" in node and "jobs" in node:
            # Fleet rows: the same row name at a different fleet size is a
            # different workload, not a comparable measurement. Dist rows
            # additionally carry the transport ("channel", "tcp") — the
            # same fleet shape over a different transport is a different
            # measurement, never a cross-diff.
            key = ("row", f"{node['row']}/jobs={node['jobs']}")
            if "transport" in node:
                key = ("row", f"{key[1]}/transport={node['transport']}")
        elif "row" in node:
            key = ("row", str(node["row"]))
        elif "units" in node and "m" in node and "isa" in node:
            # Per-ISA find_winners rows: keyed by tier so hosts with
            # different ISA support never cross-diff.
            key = ("units", f"{node['units']}/m={node['m']}/isa={node['isa']}")
        elif "units" in node and "m" in node:
            key = ("units", f"{node['units']}/m={node['m']}")
        elif "units" in node:
            key = ("units", str(node["units"]))
        if key is not None and key[0] == "row" and node.get("serve"):
            # Serve-keyed rows ("serve": true): the daemon path measures
            # protocol + scheduling on top of the fleet, so its rows must
            # never cross-diff against batch-fleet rows of the same name
            # and size.
            key = ("row", f"{key[1]}/serve")
        if key is not None and key[0] == "row" and "telemetry" in node:
            # Telemetry-keyed rows: the on/off arms of the overhead bench
            # measure different code paths, so they are separate rows —
            # never diff one against the other.
            key = ("row", f"{key[1]}/telemetry={node['telemetry']}")
        if key is not None:
            out[key] = node
        for v in node.values():
            rows_by_key(v, out)
    elif isinstance(node, list):
        for v in node:
            rows_by_key(v, out)
    return out


def timing_fields(row):
    for name, value in row.items():
        if isinstance(value, (int, float)) and (
            name.endswith("_s") or name.endswith("_ns_per_signal")
        ):
            yield name, float(value)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args()

    failures = []
    compared_any = False
    for fresh_path in sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json"))):
        name = os.path.basename(fresh_path)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(base_path):
            print(f"{name}: no committed baseline — skipped")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        if base.get("status") == "instrumented-not-measured":
            print(f"{name}: baseline is a placeholder (no real numbers) — skipped")
            continue
        if "signals" in base and "signals" in fresh and base["signals"] != fresh["signals"]:
            print(
                f"{name}: WARNING — workload mismatch (baseline recorded at "
                f"{base['signals']} signals, fresh run at {fresh['signals']}); "
                f"the regression gate is DISARMED for this file. Re-record the "
                f"baseline with MSGSN_BENCH_SIGNALS={fresh['signals']} "
                f"(the value CI runs with) and commit it."
            )
            continue
        base_rows = rows_by_key(base, {})
        fresh_rows = rows_by_key(fresh, {})
        for key, fresh_row in sorted(fresh_rows.items()):
            base_row = base_rows.get(key)
            if base_row is None:
                print(f"{name} {key[1]}: new row — skipped")
                continue
            for field, fresh_v in timing_fields(fresh_row):
                base_v = base_row.get(field)
                if not isinstance(base_v, (int, float)) or base_v <= 0:
                    continue
                compared_any = True
                ratio = fresh_v / float(base_v)
                verdict = "ok"
                if ratio > 1.0 + args.threshold:
                    verdict = "REGRESSION"
                    failures.append(
                        f"{name} [{key[1]}] {field}: {base_v:.4g} -> {fresh_v:.4g} "
                        f"({ratio:.2f}x)"
                    )
                print(
                    f"{name} [{key[1]}] {field}: {base_v:.4g} -> {fresh_v:.4g} "
                    f"({ratio:.2f}x) {verdict}"
                )

    if failures:
        print(f"\n{len(failures)} timing regression(s) beyond "
              f"{100 * args.threshold:.0f}%:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    if not compared_any:
        print("\nno comparable real numbers yet — nothing to diff")
    else:
        print("\nno regressions beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
