"""Unit tests for the bench regression gate (scripts/compare_bench.py).

The gate is load-bearing CI: a silent mis-skip would let regressions land,
a spurious failure would block every PR. These tests pin its contract:

- a committed placeholder ("instrumented-not-measured") is skipped;
- a workload-scale mismatch disarms the diff with a loud warning;
- a timing regression beyond the threshold fails (exit 1);
- within-threshold drift and speedups pass;
- a fresh file with no committed counterpart is skipped;
- fleet rows key on (row, jobs): a regression at the same fleet size
  fails, while the same row name at a different fleet size is a new row
  (skipped), never a cross-size diff;
- dist rows key on (row, jobs, transport): a regression on the same
  transport fails, while the same shape over a different transport
  ("channel" vs "tcp") is a new row (skipped), never a cross-diff;
- per-ISA find_winners rows key on (units, m, isa): a regression on the
  same tier fails, while a tier only one host supports is a new row
  (skipped) — baselines from hosts with different ISA support never
  cross-diff;
- serve rows ("serve": true) key on (row, jobs, serve): a regression on
  the daemon path fails against the serve baseline, while serve and
  batch-fleet rows of the same name and size never cross-diff;
- telemetry rows ("telemetry": "off"/"on") key on (row, telemetry): a
  regression in one arm fails against that arm's own baseline, while the
  instrumented and uninstrumented arms never cross-diff.

Runnable with the stdlib alone (`python3 -m unittest discover -s scripts`)
or with pytest.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "compare_bench.py")


def run_compare(baseline, fresh, threshold=0.10):
    return subprocess.run(
        [
            sys.executable,
            SCRIPT,
            "--baseline",
            baseline,
            "--fresh",
            fresh,
            "--threshold",
            str(threshold),
        ],
        capture_output=True,
        text=True,
        check=False,
    )


def bench_payload(signals=60000, total_s=1.0, row="multi"):
    return {
        "bench": "update_phase",
        "signals": signals,
        "drivers": [
            {"row": row, "driver": "multi", "total_s": total_s, "units": 300}
        ],
    }


def fleet_payload(jobs=2, concurrent_s=1.0, sequential_s=2.0):
    return {
        "bench": "end_to_end",
        "fleet": [
            {"row": "fleet-concurrent", "jobs": jobs, "total_s": concurrent_s},
            {"row": "fleet-sequential", "jobs": jobs, "total_s": sequential_s},
        ],
    }


def isa_payload(rows):
    """find_winners-style payload; rows = [(units, m, isa, multi_s), …]."""
    return {
        "bench": "find_winners",
        "per_signal_seconds": [
            {"units": n, "m": m, "isa": isa, "multi_s": t} for n, m, isa, t in rows
        ],
    }


class CompareBenchCase(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.baseline = os.path.join(self._tmp.name, "baseline")
        self.fresh = os.path.join(self._tmp.name, "fresh")
        os.makedirs(self.baseline)
        os.makedirs(self.fresh)

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, where, name, payload):
        with open(os.path.join(where, name), "w") as f:
            json.dump(payload, f)

    def test_placeholder_baseline_is_skipped(self):
        self.write(
            self.baseline,
            "BENCH_update_phase.json",
            {"status": "instrumented-not-measured", "bench": "update_phase"},
        )
        self.write(self.fresh, "BENCH_update_phase.json", bench_payload(total_s=99.0))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("placeholder", r.stdout)
        self.assertIn("nothing to diff", r.stdout)

    def test_workload_scale_mismatch_disarms_the_gate(self):
        self.write(
            self.baseline, "BENCH_update_phase.json", bench_payload(signals=300000)
        )
        # A huge regression at the wrong scale must NOT fail — but must warn.
        self.write(
            self.fresh,
            "BENCH_update_phase.json",
            bench_payload(signals=60000, total_s=50.0),
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("DISARMED", r.stdout)
        self.assertIn("MSGSN_BENCH_SIGNALS=60000", r.stdout)

    def test_regression_beyond_threshold_fails(self):
        self.write(self.baseline, "BENCH_update_phase.json", bench_payload(total_s=1.0))
        self.write(self.fresh, "BENCH_update_phase.json", bench_payload(total_s=1.2))
        r = run_compare(self.baseline, self.fresh, threshold=0.10)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("REGRESSION", r.stdout)
        self.assertIn("regression(s) beyond", r.stderr)

    def test_within_threshold_passes(self):
        self.write(self.baseline, "BENCH_update_phase.json", bench_payload(total_s=1.0))
        self.write(self.fresh, "BENCH_update_phase.json", bench_payload(total_s=1.05))
        r = run_compare(self.baseline, self.fresh, threshold=0.10)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("no regressions beyond the threshold", r.stdout)

    def test_speedups_never_fail(self):
        self.write(self.baseline, "BENCH_update_phase.json", bench_payload(total_s=1.0))
        self.write(self.fresh, "BENCH_update_phase.json", bench_payload(total_s=0.2))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)

    def test_missing_baseline_file_is_skipped(self):
        self.write(self.fresh, "BENCH_new_bench.json", bench_payload(total_s=9.0))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("no committed baseline", r.stdout)

    def test_new_row_in_fresh_file_is_skipped(self):
        # A fresh file may gain rows (e.g. the PR 4 region rows) without
        # disarming the diff of the rows both files share.
        self.write(self.baseline, "BENCH_update_phase.json", bench_payload(total_s=1.0))
        fresh = bench_payload(total_s=1.0)
        fresh["drivers"].append(
            {"row": "par regions", "driver": "parallel", "regions": 64, "total_s": 0.5}
        )
        self.write(self.fresh, "BENCH_update_phase.json", fresh)
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)
        self.assertIn("no regressions beyond the threshold", r.stdout)

    def test_fleet_row_regression_fails_at_same_size(self):
        self.write(self.baseline, "BENCH_end_to_end.json", fleet_payload(concurrent_s=1.0))
        self.write(self.fresh, "BENCH_end_to_end.json", fleet_payload(concurrent_s=1.5))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("fleet-concurrent/jobs=2", r.stdout)
        self.assertIn("REGRESSION", r.stdout)

    def test_fleet_rows_at_different_sizes_never_diff(self):
        # Re-sizing the fleet bench is a new workload: a huge "regression"
        # between jobs=2 and jobs=8 rows must be a new-row skip, not a
        # failure.
        self.write(self.baseline, "BENCH_end_to_end.json", fleet_payload(jobs=2))
        self.write(
            self.fresh,
            "BENCH_end_to_end.json",
            fleet_payload(jobs=8, concurrent_s=50.0, sequential_s=99.0),
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)

    def test_fleet_jobs_count_is_not_a_timing_field(self):
        # "jobs" is key material, never a compared metric.
        self.write(self.baseline, "BENCH_end_to_end.json", fleet_payload())
        fresh = fleet_payload()
        fresh["fleet"][0]["jobs"] = 2  # unchanged key, same rows
        self.write(self.fresh, "BENCH_end_to_end.json", fresh)
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("no regressions beyond the threshold", r.stdout)

    def test_dist_row_regression_fails_on_same_transport(self):
        def dist_payload(total_s):
            return {
                "bench": "end_to_end",
                "dist": [
                    {
                        "row": "dist-fleet",
                        "jobs": 2,
                        "transport": "channel",
                        "total_s": total_s,
                    }
                ],
            }

        self.write(self.baseline, "BENCH_end_to_end.json", dist_payload(1.0))
        self.write(self.fresh, "BENCH_end_to_end.json", dist_payload(1.5))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("dist-fleet/jobs=2/transport=channel", r.stdout)
        self.assertIn("REGRESSION", r.stdout)

    def test_dist_rows_on_different_transports_never_cross_diff(self):
        # The same dist shape over TCP instead of the in-process channel is
        # a different measurement: a huge "regression" between them must be
        # a new-row skip, not a failure.
        def dist_payload(transport, total_s):
            return {
                "bench": "end_to_end",
                "dist": [
                    {
                        "row": "dist-fleet",
                        "jobs": 2,
                        "transport": transport,
                        "total_s": total_s,
                    }
                ],
            }

        self.write(self.baseline, "BENCH_end_to_end.json", dist_payload("channel", 1.0))
        self.write(self.fresh, "BENCH_end_to_end.json", dist_payload("tcp", 50.0))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)

    def serve_payload(self, total_s, serve=True, row="serve-fleet"):
        entry = {"row": row, "jobs": 2, "total_s": total_s}
        if serve:
            entry["serve"] = True
        return {"bench": "end_to_end", "serve": [entry]}

    def test_serve_row_regression_fails_against_serve_baseline(self):
        self.write(self.baseline, "BENCH_end_to_end.json", self.serve_payload(1.0))
        self.write(self.fresh, "BENCH_end_to_end.json", self.serve_payload(1.5))
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("serve-fleet/jobs=2/serve", r.stdout)
        self.assertIn("REGRESSION", r.stdout)

    def test_serve_and_batch_rows_never_cross_diff(self):
        # A daemon-path row must not diff against a batch-fleet row of the
        # same name and size: the serve row measures protocol + scheduling
        # on top of the fleet, so a huge delta between them is two
        # workloads, not a regression.
        self.write(
            self.baseline,
            "BENCH_end_to_end.json",
            self.serve_payload(1.0, serve=False, row="fleet-concurrent"),
        )
        self.write(
            self.fresh,
            "BENCH_end_to_end.json",
            self.serve_payload(50.0, serve=True, row="fleet-concurrent"),
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)

    def telemetry_payload(self, off_s, on_s):
        return {
            "bench": "end_to_end",
            "telemetry": [
                {"row": "telemetry-overhead", "telemetry": "off", "total_s": off_s},
                {"row": "telemetry-overhead", "telemetry": "on", "total_s": on_s},
            ],
        }

    def test_telemetry_row_regression_fails_within_same_arm(self):
        self.write(
            self.baseline, "BENCH_end_to_end.json", self.telemetry_payload(1.0, 1.02)
        )
        self.write(
            self.fresh, "BENCH_end_to_end.json", self.telemetry_payload(1.0, 1.5)
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("telemetry-overhead/telemetry=on", r.stdout)
        self.assertIn("REGRESSION", r.stdout)

    def test_telemetry_on_and_off_arms_never_cross_diff(self):
        # Without the telemetry key suffix the two arms would collide on
        # ("row", "telemetry-overhead") and the later row would silently
        # shadow the earlier one — the gate would then diff an "on" fresh
        # number against an "off" baseline. The suffix keeps the arms as
        # two separate rows, so an arm present on only one side is a
        # new-row skip, never a cross-arm failure.
        self.write(
            self.baseline,
            "BENCH_end_to_end.json",
            {
                "bench": "end_to_end",
                "telemetry": [
                    {"row": "telemetry-overhead", "telemetry": "off", "total_s": 1.0}
                ],
            },
        )
        self.write(
            self.fresh,
            "BENCH_end_to_end.json",
            {
                "bench": "end_to_end",
                "telemetry": [
                    {"row": "telemetry-overhead", "telemetry": "on", "total_s": 50.0}
                ],
            },
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)

    def test_isa_row_regression_fails_on_same_tier(self):
        self.write(
            self.baseline,
            "BENCH_find_winners.json",
            isa_payload([(8192, 8192, "avx2", 1.0e-7)]),
        )
        self.write(
            self.fresh,
            "BENCH_find_winners.json",
            isa_payload([(8192, 8192, "avx2", 2.0e-7)]),
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("8192/m=8192/isa=avx2", r.stdout)
        self.assertIn("REGRESSION", r.stdout)

    def test_isa_rows_from_different_hosts_never_cross_diff(self):
        # Baseline recorded on an AVX-512 host, fresh run on an AVX2-only
        # host: the avx512 row simply has no fresh counterpart and the
        # fresh avx2 row is new — neither may fail, even with times that
        # would be a huge "regression" under a tier-blind (units, m) key.
        self.write(
            self.baseline,
            "BENCH_find_winners.json",
            isa_payload([(8192, 8192, "avx512", 1.0e-8)]),
        )
        self.write(
            self.fresh,
            "BENCH_find_winners.json",
            isa_payload([(8192, 8192, "avx2", 5.0e-7)]),
        )
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("new row", r.stdout)

    def test_non_timing_fields_are_ignored(self):
        # `units`, counters etc. must never trip the gate.
        self.write(self.baseline, "BENCH_update_phase.json", bench_payload(total_s=1.0))
        fresh = bench_payload(total_s=1.0)
        fresh["drivers"][0]["units"] = 9999
        self.write(self.fresh, "BENCH_update_phase.json", fresh)
        r = run_compare(self.baseline, self.fresh)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)


if __name__ == "__main__":
    unittest.main()
