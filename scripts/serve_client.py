#!/usr/bin/env python3
"""Line-JSON client for `msgsn serve` — the CI serve-e2e driver.

Subcommands:

  session       drive a full daemon conversation: submit every job from a
                manifest, subscribe to watch, poll status until all jobs
                finish, query every job (units / mesh / snapshot), then
                request shutdown and read the stream to the `bye` event.
                Reconnects with bounded retries when the daemon severs the
                connection (the chaos cell injects exactly that), treating
                the `exists` code on resubmission as success.
  metrics       poll the daemon's `metrics` verb twice: assert both polls
                answer ok, the Prometheus text pane is non-empty, and every
                counter is monotone non-decreasing between polls. The verb
                answers from the telemetry registry only, so this is safe to
                run at any point in the daemon's life — including while
                sessions are mid-convergence.
  check-report  assert on a --report-json file: every row done, exit 0.

Exit codes: 0 success, 1 assertion/protocol failure, 2 could not connect.

Stdlib only — runs on the bare CI python3.
"""

import argparse
import json
import socket
import sys
import time

CONNECT_RETRIES = 40
CONNECT_DELAY = 0.25
RECONNECT_RETRIES = 5
LINE_TIMEOUT = 120.0


def log(msg):
    print(f"serve_client: {msg}", flush=True)


class Severed(Exception):
    """The daemon closed the connection (EOF mid-conversation)."""


class Client:
    """One TCP connection speaking the line-JSON protocol."""

    def __init__(self, addr):
        host, port = addr.rsplit(":", 1)
        last = None
        for _ in range(CONNECT_RETRIES):
            try:
                self.sock = socket.create_connection((host, int(port)), timeout=5)
                break
            except OSError as e:
                last = e
                time.sleep(CONNECT_DELAY)
        else:
            log(f"cannot connect to {addr}: {last}")
            sys.exit(2)
        self.sock.settimeout(LINE_TIMEOUT)
        self.reader = self.sock.makefile("r", encoding="utf-8")

    def send(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode("utf-8"))

    def recv(self):
        line = self.reader.readline()
        if not line:
            raise Severed("daemon closed the connection")
        return json.loads(line)

    def request(self, obj, events):
        """Send and read to the response, routing events aside."""
        self.send(obj)
        while True:
            doc = self.recv()
            if "ok" in doc:
                return doc
            events.append(doc)

    def close(self):
        try:
            self.reader.close()
            self.sock.close()
        except OSError:
            pass


class Session:
    """The scripted conversation, with reconnect-on-EOF."""

    def __init__(self, addr):
        self.addr = addr
        self.events = []
        self.reconnects = 0
        self.client = Client(addr)
        self.watching = False

    def reconnect(self):
        self.reconnects += 1
        if self.reconnects > RECONNECT_RETRIES:
            raise SystemExit("serve_client: reconnect budget exhausted")
        log(f"connection severed — reconnecting ({self.reconnects}/{RECONNECT_RETRIES})")
        self.client.close()
        self.client = Client(self.addr)
        if self.watching:
            # watch subscriptions are per-connection; re-arm.
            resp = self.client.request({"cmd": "watch"}, self.events)
            assert_ok(resp, "re-watch")

    def request(self, obj, ok_codes=()):
        """Request with reconnect; `ok_codes` are failure codes treated as
        success (e.g. `exists` when resubmitting after a severed submit)."""
        while True:
            try:
                resp = self.client.request(obj, self.events)
            except Severed:
                self.reconnect()
                continue
            if resp.get("ok"):
                return resp
            if resp.get("code") in ok_codes:
                log(f"{obj.get('cmd')}: tolerated code {resp.get('code')!r}")
                return resp
            raise SystemExit(f"serve_client: {obj.get('cmd')} failed: {resp}")

    def watch(self):
        self.request({"cmd": "watch"})
        self.watching = True

    def drain_to_bye(self):
        while True:
            try:
                doc = self.client.recv()
            except Severed:
                self.reconnect()
                # Draining continues; the daemon rebroadcasts nothing, but
                # status still answers — fall back to polling below.
                return None
            self.events.append(doc)
            if doc.get("event") == "bye":
                return doc


def assert_ok(resp, label):
    if not resp.get("ok"):
        raise SystemExit(f"serve_client: {label} failed: {resp}")


def load_jobs(path, max_signals):
    with open(path, "r", encoding="utf-8") as f:
        manifest = json.load(f)
    jobs = manifest["jobs"]
    if max_signals is not None:
        for job in jobs:
            job.setdefault("config", {})["max_signals"] = max_signals
    return jobs


def cmd_session(args):
    deadline = time.monotonic() + args.timeout
    jobs = load_jobs(args.jobs, args.max_signals)
    if args.expect_jobs is not None and len(jobs) != args.expect_jobs:
        raise SystemExit(
            f"serve_client: manifest has {len(jobs)} jobs, expected {args.expect_jobs}"
        )
    names = [job["name"] for job in jobs]
    session = Session(args.connect)
    session.watch()

    for job in jobs:
        resp = session.request({"cmd": "submit", "job": job}, ok_codes=("exists",))
        log(f"submitted {job['name']}: {resp}")

    # Poll status until every job reports done (watch events stream in on
    # the side and are collected for the final sanity checks).
    while True:
        if time.monotonic() > deadline:
            raise SystemExit("serve_client: timed out waiting for jobs to finish")
        resp = session.request({"cmd": "status"})
        rows = {row["name"]: row for row in resp["jobs"]}
        missing = [n for n in names if n not in rows]
        if missing:
            # A submit acknowledged before a drop may have been the
            # duplicate — resubmit idempotently.
            for job in jobs:
                if job["name"] in missing:
                    session.request({"cmd": "submit", "job": job}, ok_codes=("exists",))
            continue
        states = {n: rows[n]["status"] for n in names}
        log(f"status: {states}")
        bad = [n for n, s in states.items() if s in ("failed", "quarantined")]
        if bad:
            raise SystemExit(f"serve_client: jobs failed: {bad}")
        if all(s == "done" for s in states.values()):
            break
        time.sleep(args.poll_secs)

    # Every read view answers for every finished job.
    for name in names:
        for what in ("units", "mesh", "snapshot"):
            resp = session.request({"cmd": "query", "job": name, "what": what})
            view = resp.get("view", {})
            log(f"query {name}/{what}: {view}")
            if what == "units" and view.get("units", 0) <= 0:
                raise SystemExit(f"serve_client: {name} reports no units: {resp}")
            if what == "snapshot" and not view.get("crc32"):
                raise SystemExit(f"serve_client: {name} snapshot probe empty: {resp}")

    session.request({"cmd": "shutdown"})
    bye = session.drain_to_bye()
    if bye is None:
        log("severed during drain — daemon exit code must prove the drain instead")
    else:
        log(f"bye: {bye}")
        if bye.get("exit") != 0:
            raise SystemExit(f"serve_client: daemon drained with exit {bye.get('exit')}")
        report = [e for e in session.events if e.get("event") == "report"]
        if not report:
            raise SystemExit("serve_client: no report event before bye")
        rows = report[-1]["rows"]
        if sorted(r["name"] for r in rows) != sorted(names):
            raise SystemExit(f"serve_client: report rows mismatch: {rows}")

    done_events = {e["job"]["name"] for e in session.events if e.get("event") == "done"}
    progress = sum(1 for e in session.events if e.get("event") == "progress")
    log(f"events: {len(session.events)} total, {progress} progress, done={sorted(done_events)}")
    log("session complete")
    return 0


def poll_metrics(session):
    """One `metrics` request; returns (counters_dict, prometheus_text)."""
    resp = session.request({"cmd": "metrics"})
    metrics = resp.get("metrics", {})
    text = resp.get("text", "")
    if not isinstance(metrics, dict) or "counters" not in metrics:
        raise SystemExit(f"serve_client: metrics response missing counters: {resp}")
    counters = metrics["counters"]
    if not text or "# TYPE" not in text:
        raise SystemExit("serve_client: metrics response has no Prometheus text pane")
    return counters, text


def cmd_metrics(args):
    session = Session(args.connect)
    first, text = poll_metrics(session)
    log(f"metrics poll 1: {len(first)} counters")
    time.sleep(args.gap_secs)
    second, _ = poll_metrics(session)
    log(f"metrics poll 2: {len(second)} counters")
    regressed = [
        name
        for name, value in first.items()
        if second.get(name, 0) < value
    ]
    if regressed:
        raise SystemExit(f"serve_client: counters regressed between polls: {regressed}")
    # The daemon served at least these two requests, so the serve counters
    # must have moved by the second poll.
    if second.get("msgsn_serve_requests_total", 0) <= 0:
        raise SystemExit(f"serve_client: msgsn_serve_requests_total never moved: {second}")
    for line in text.splitlines()[:6]:
        log(f"prometheus: {line}")
    session.client.close()
    log("metrics complete")
    return 0


def cmd_check_report(args):
    with open(args.path, "r", encoding="utf-8") as f:
        report = json.load(f)
    rows = report["rows"]
    if args.expect_jobs is not None and len(rows) != args.expect_jobs:
        raise SystemExit(f"check-report: {len(rows)} rows, expected {args.expect_jobs}")
    not_done = [r["name"] for r in rows if r["status"] != "done"]
    if not_done:
        raise SystemExit(f"check-report: jobs not done: {not_done}")
    if report.get("outcome") != "all-succeeded" or report.get("exit_code") != 0:
        raise SystemExit(
            f"check-report: outcome {report.get('outcome')!r} "
            f"exit_code {report.get('exit_code')!r}"
        )
    for r in rows:
        run = r.get("report") or {}
        if not run.get("converged") or run.get("units", 0) <= 0:
            raise SystemExit(f"check-report: row {r['name']} did not converge: {r}")
    log(f"check-report: {len(rows)} rows all done, outcome all-succeeded")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="sub", required=True)

    s = sub.add_parser("session", help="drive a full daemon conversation")
    s.add_argument("--connect", default="127.0.0.1:7081")
    s.add_argument("--jobs", required=True, help="jobs manifest to submit from")
    s.add_argument("--max-signals", type=int, default=None,
                   help="override each job's max_signals (CI wall-clock)")
    s.add_argument("--expect-jobs", type=int, default=None)
    s.add_argument("--poll-secs", type=float, default=0.5)
    s.add_argument("--timeout", type=float, default=300.0)
    s.set_defaults(fn=cmd_session)

    m = sub.add_parser("metrics", help="poll the metrics verb and assert monotonicity")
    m.add_argument("--connect", default="127.0.0.1:7081")
    m.add_argument("--gap-secs", type=float, default=0.5,
                   help="pause between the two polls")
    m.set_defaults(fn=cmd_metrics)

    c = sub.add_parser("check-report", help="assert on a --report-json file")
    c.add_argument("path")
    c.add_argument("--expect-jobs", type=int, default=None)
    c.set_defaults(fn=cmd_check_report)

    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
