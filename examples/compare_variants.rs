//! Compare all four paper implementations on one mesh — a miniature of the
//! paper's Tables 1–4 that runs in seconds.
//!
//! ```sh
//! make artifacts && cargo run --release --example compare_variants -- blob
//! ```

use msgsn::bench::{grid::run_grid, render::render_table, Scale};
use msgsn::config::Driver;
use msgsn::mesh::BenchmarkShape;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let shape = args
        .get(1)
        .and_then(|s| BenchmarkShape::from_name(s))
        .unwrap_or(BenchmarkShape::Blob);

    // Which drivers can run here? PJRT needs the AOT artifacts.
    let mut drivers = vec![Driver::Single, Driver::Indexed, Driver::Multi];
    if std::path::Path::new("artifacts/manifest.json").exists() {
        drivers.push(Driver::Pjrt);
    } else {
        eprintln!("note: artifacts/ missing — skipping the GPU-based (pjrt) column");
    }

    let grid = run_grid(&[shape], &drivers, &Scale::SMOKE, 42, None, |line| {
        println!("{line}")
    })?;

    let table_no = match shape {
        BenchmarkShape::Blob => 1,
        BenchmarkShape::Eight => 2,
        BenchmarkShape::Hand => 3,
        BenchmarkShape::Heptoroid => 4,
    };
    let (text, _) = render_table(&grid, table_no)?;
    println!("\n{text}");
    println!(
        "(smoke scale: tiny networks, short cap — run `msgsn reproduce` for \
         the real tables)"
    );
    Ok(())
}
