// perf probe: BatchRust tile sweep + scalar SoA experiment
use std::time::{Duration, Instant};
use msgsn::findwinners::{BatchRust, FindWinners, Scalar};
use msgsn::geometry::Vec3;
use msgsn::rng::Rng;
use msgsn::som::Network;

fn random_net(n: usize, seed: u64) -> Network {
    let mut rng = Rng::seed_from(seed);
    let mut net = Network::new();
    for _ in 0..n { net.insert(Vec3::new(rng.f32(), rng.f32(), rng.f32()), 0.1); }
    net
}

fn main() {
    let n = 8192;
    let m = 8192;
    let net = random_net(n, 1);
    let mut rng = Rng::seed_from(2);
    let signals: Vec<Vec3> = (0..m).map(|_| Vec3::new(rng.f32(), rng.f32(), rng.f32())).collect();
    let mut out = Vec::new();
    println!("BatchRust tile sweep (m=n=8192, s/signal):");
    for tile in [64, 128, 256, 512, 1024, 2048, 8192] {
        let mut fw = BatchRust::new(tile);
        fw.find2_batch(&net, &signals, &mut out);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            let mut iters = 0;
            while t0.elapsed() < Duration::from_millis(300) { fw.find2_batch(&net, &signals, &mut out); iters += 1; }
            best = best.min(t0.elapsed().as_secs_f64() / (iters as f64 * m as f64));
        }
        println!("  tile {:5}: {:.3e}", tile, best);
    }
    // signal-blocked variant: process signals in blocks of B over each tile to keep tile hot
    println!("scalar single-signal (s/signal):");
    let mut sc = Scalar::new();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut done = 0usize;
        while t0.elapsed() < Duration::from_millis(300) {
            std::hint::black_box(sc.find2(&net, signals[done % m]));
            done += 1;
        }
        best = best.min(t0.elapsed().as_secs_f64() / done as f64);
    }
    println!("  AoS walk: {:.3e}", best);
    // SoA probe: dense position arrays
    let mut px = Vec::with_capacity(n); let mut py = Vec::with_capacity(n); let mut pz = Vec::with_capacity(n);
    for id in net.ids() { let p = net.pos(id); px.push(p.x); py.push(p.y); pz.push(p.z); }
    let mut best2 = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut done = 0usize;
        while t0.elapsed() < Duration::from_millis(300) {
            let s = signals[done % m];
            let mut d1 = f32::INFINITY; let mut d2 = f32::INFINITY; let mut i1 = 0u32; let mut i2 = 0u32;
            for k in 0..px.len() {
                let dx = s.x - px[k]; let dy = s.y - py[k]; let dz = s.z - pz[k];
                let d = dx*dx + dy*dy + dz*dz;
                if d < d1 { d2 = d1; i2 = i1; d1 = d; i1 = k as u32; }
                else if d < d2 { d2 = d; i2 = k as u32; }
            }
            std::hint::black_box((i1, i2, d1, d2));
            done += 1;
        }
        best2 = best2.min(t0.elapsed().as_secs_f64() / done as f64);
    }
    println!("  SoA walk: {:.3e}", best2);
    // interleaved xyz contiguous array (AoS dense, no alive checks)
    let mut flat: Vec<f32> = Vec::with_capacity(n*3);
    for id in net.ids() { let p = net.pos(id); flat.extend_from_slice(&[p.x, p.y, p.z]); }
    let mut best3 = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut done = 0usize;
        while t0.elapsed() < Duration::from_millis(300) {
            let s = signals[done % m];
            let mut d1 = f32::INFINITY; let mut d2 = f32::INFINITY; let mut i1 = 0u32; let mut i2 = 0u32;
            for (k, c) in flat.chunks_exact(3).enumerate() {
                let dx = s.x - c[0]; let dy = s.y - c[1]; let dz = s.z - c[2];
                let d = dx*dx + dy*dy + dz*dz;
                if d < d1 { d2 = d1; i2 = i1; d1 = d; i1 = k as u32; }
                else if d < d2 { d2 = d; i2 = k as u32; }
            }
            std::hint::black_box((i1, i2, d1, d2));
            done += 1;
        }
        best3 = best3.min(t0.elapsed().as_secs_f64() / done as f64);
    }
    println!("  dense AoS walk: {:.3e}", best3);
}
