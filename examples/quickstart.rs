//! Quickstart: reconstruct a genus-2 surface (the paper's "Eight" mesh)
//! with the multi-signal SOAM and print the paper-style report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use msgsn::config::{Driver, RunConfig};
use msgsn::engine::{run, ConvergenceSession};
use msgsn::fleet::snapshot;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape};
use msgsn::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A benchmark point-cloud source: implicit double torus, polygonized
    //    by marching tetrahedra, normalized to the unit cube.
    let mesh = benchmark_mesh(BenchmarkShape::Eight, 48);
    let stats = mesh.stats();
    println!(
        "source mesh: {} vertices, {} faces, genus {:?}",
        stats.vertices,
        stats.faces,
        stats.genus
    );

    // 2. The tuned per-mesh preset (paper §3.1), scaled up for a fast demo:
    //    larger insertion threshold -> fewer units -> seconds, not minutes.
    let mut cfg = RunConfig::preset(BenchmarkShape::Eight);
    cfg.soam.insertion_threshold *= 2.0;
    cfg.limits.max_signals = 3_000_000;

    // 3. Run the multi-signal variant (the paper's contribution): batched
    //    Find Winners + winner-lock Update. The region partition (64
    //    spatial regions) keeps the Find Winners scan local to each
    //    signal's neighborhood — results are bit-identical to regions = 1;
    //    only wall time changes. (Same for update_threads/find_threads on
    //    the parallel/pipelined drivers.)
    cfg.regions = 64;
    let mut rng = Rng::seed_from(42);
    let report = run(&mesh, Driver::Multi, &cfg, &mut rng)?;
    print!("{}", report.to_table().render());

    if report.converged {
        println!(
            "\nconverged: every unit's neighborhood is a closed disk — the \
             network is a triangulated 2-manifold."
        );
    } else {
        println!("\nhit the signal cap before topological convergence.");
    }

    // 4. Checkpoint/resume (the fleet subsystem): a run is a resumable
    //    ConvergenceSession — step it, snapshot it at any batch boundary,
    //    kill it, and a restored session finishes bit-identically to never
    //    having stopped. (`msgsn fleet --jobs examples/fleet.json
    //    --checkpoint-every 64` does this for N concurrent jobs.)
    let mut demo_cfg = cfg.clone();
    demo_cfg.limits.max_signals = 60_000;
    demo_cfg.driver = Driver::Multi;

    let mut session = ConvergenceSession::new(&demo_cfg, &mesh, None)?;
    session.step(40); // run 40 batches…
    let checkpoint = snapshot::snapshot_session(&session); // …snapshot…
    drop(session); // …and "crash".

    let mut resumed = ConvergenceSession::new(&demo_cfg, &mesh, None)?;
    snapshot::restore_session(&mut resumed, &checkpoint)
        .map_err(anyhow::Error::msg)?;
    let resumed_report = resumed.run_to_end();

    let mut uninterrupted = ConvergenceSession::new(&demo_cfg, &mesh, None)?;
    let straight_report = uninterrupted.run_to_end();
    println!(
        "\ncheckpoint/resume demo: resumed run {} units / qe {:e}, \
         uninterrupted {} units / qe {:e} — bit-identical: {}",
        resumed_report.units,
        resumed_report.qe,
        straight_report.units,
        straight_report.qe,
        resumed_report.units == straight_report.units
            && resumed_report.qe.to_bits() == straight_report.qe.to_bits(),
    );
    Ok(())
}
