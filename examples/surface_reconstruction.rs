//! End-to-end driver (DESIGN.md "End-to-end validation"): the full system on
//! a real workload, proving all three layers compose.
//!
//! Pipeline: implicit surface → marching tetrahedra → point-cloud sampler →
//! multi-signal SOAM with the **PJRT-executed AOT Find-Winners artifact**
//! (Layer 1/2 compiled from python/compile/, loaded by rust) → reconstructed
//! triangulation → topology verification (genus must match the source) →
//! OBJ export.
//!
//! ```sh
//! make artifacts && cargo run --release --example surface_reconstruction
//! # optional: mesh name and signal cap
//! cargo run --release --example surface_reconstruction -- eight 4000000
//! ```

use std::path::Path;

use msgsn::config::{Driver, RunConfig};
use msgsn::engine::{make_algorithm, make_findwinners, run_multi_signal};
use msgsn::mesh::{benchmark_mesh, write_obj, BenchmarkShape, SurfaceSampler};
use msgsn::rng::Rng;
use msgsn::topology::euler_characteristic;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let shape = args
        .get(1)
        .and_then(|s| BenchmarkShape::from_name(s))
        .unwrap_or(BenchmarkShape::Eight);
    let max_signals: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6_000_000);

    // Layer-3 substrate: source geometry and sampler.
    let mesh = benchmark_mesh(shape, 0);
    let source = mesh.stats();
    println!(
        "[1/4] source `{}` ({}): genus {:?}, area {:.3}",
        shape.name(),
        shape.paper_name(),
        source.genus,
        source.total_area
    );

    // Layers 1+2: the AOT-compiled batched Find Winners, via PJRT.
    let mut cfg = RunConfig::preset(shape);
    cfg.driver = Driver::Pjrt;
    // Demo scale: ~1/4 of the paper-size network so the run takes seconds.
    cfg.soam.insertion_threshold *= 2.0;
    cfg.limits.max_signals = max_signals;
    if !Path::new("artifacts/manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let mut fw = make_findwinners(&cfg)?;
    let mut algo = make_algorithm(&cfg);
    println!("[2/4] PJRT runtime ready (flavor per manifest default)");

    // Run the multi-signal SOAM to topological convergence.
    let sampler = SurfaceSampler::new(&mesh);
    let mut rng = Rng::seed_from(7);
    let report = run_multi_signal(algo.as_mut(), &sampler, fw.as_mut(), &cfg.limits, &mut rng);
    println!(
        "[3/4] {}: {} units, {} connections, {} signals ({} discarded), {:.2}s — converged={}",
        if report.converged { "converged" } else { "cap hit" },
        report.units,
        report.connections,
        report.signals,
        report.discarded,
        report.total.as_secs_f64(),
        report.converged,
    );

    // Verify the reconstruction's topology against the source.
    let adj = algo.net().adjacency_map();
    let chi = euler_characteristic(&adj);
    let genus = (2 - chi) / 2;
    println!(
        "[4/4] reconstruction: Euler characteristic {chi} -> genus {genus} \
         (source {})",
        shape.expected_genus()
    );
    if report.converged {
        assert_eq!(
            genus as u32,
            shape.expected_genus(),
            "reconstructed genus must match the source at convergence"
        );
        println!("      topology PRESERVED — the paper's Fig. 1 property.");
    }

    let out = format!("reconstruction_{}.obj", shape.name());
    write_obj(&algo.net().to_mesh(), Path::new(&out))?;
    println!("      wrote {out}");
    Ok(())
}
