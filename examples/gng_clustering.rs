//! Framework generality: the same drivers run Growing Neural Gas and GWR —
//! the two prior growing networks the paper builds on (§2.1) — including
//! under the multi-signal variant. GNG/GWR terminate on quantization error
//! rather than topology.
//!
//! ```sh
//! cargo run --release --example gng_clustering
//! ```

use msgsn::config::{Algorithm, Driver, RunConfig};
use msgsn::engine::run;
use msgsn::mesh::{benchmark_mesh, BenchmarkShape};
use msgsn::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mesh = benchmark_mesh(BenchmarkShape::Blob, 32);
    println!("GNG / GWR on the blob cloud, single- and multi-signal:\n");
    println!(
        "{:10} {:8} {:>8} {:>10} {:>12} {:>10}",
        "algorithm", "driver", "units", "signals", "qe", "seconds"
    );

    for algorithm in [Algorithm::Gng, Algorithm::Gwr] {
        for driver in [Driver::Single, Driver::Multi] {
            let mut cfg = RunConfig::preset(BenchmarkShape::Blob);
            cfg.algorithm = algorithm;
            cfg.gwr.insertion_threshold = 0.12;
            cfg.gng.lambda = 200;
            // Terminate when the quantization-error EMA crosses the target.
            cfg.gwr.target_qe = 3e-3;
            cfg.gng.target_qe = 3e-3;
            cfg.limits.max_signals = 400_000;
            cfg.limits.check_interval = 500;
            let mut rng = Rng::seed_from(9);
            let r = run(&mesh, driver, &cfg, &mut rng)?;
            println!(
                "{:10} {:8} {:>8} {:>10} {:>12.3e} {:>10.3}",
                r.algorithm,
                r.implementation,
                r.units,
                r.signals,
                r.qe,
                r.total.as_secs_f64()
            );
        }
    }
    println!(
        "\nBoth algorithms accept the multi-signal batching unchanged — the \
         variant is algorithm-agnostic (it only touches the driver loop)."
    );
    Ok(())
}
